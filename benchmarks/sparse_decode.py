"""Sparse/compressed decode analysis — what actually bounds the decode cells,
and which compression lever (paper §IV) moves each regime.

Measured finding (see decode_regimes()): at decode_32k's batch of 128 slots
the memory term is **KV-cache streaming** (the whole 32k-token cache is read
every step; weights amortize over the 128 slots — weight-stream share < 1%).
Weight sparsity (BCSC, the paper's Sparse PE) therefore pays at *small
batch*, while at large batch the paper-faithful compression move is applying
the same keep-it-compressed idea to the **cache** (int8 KV ≈ ×2 bytes).

ISSUE 1 additions:
* ``kernel_proxy`` — dense rs_matmul vs bcsc_gemv at decode shapes, grid-step
  counts (the interpret-mode proxy; on TPU the same harness wall-clocks).
* ``decode_benchmark`` — DecodeEngine tokens/sec, dense vs BCSC-packed params
  at batch {1, 4, 8}; written to BENCH_sparse_decode.json.

ISSUE 2 additions (the end-to-end gap PR 1 left):
* ``mlp_proxy`` — fused bcsc_mlp megakernel vs the two-call path: grid steps,
  payload block visits, and an HBM-bytes-moved model including the hidden-
  activation round-trip the megakernel eliminates. Wall-clock-free, so the
  CI perf guard (scripts/perf_guard.py) can enforce it in interpret mode.
* ``decode_benchmark`` now reports the sparse/dense end-to-end ratio as a
  first-class metric (vs the recorded PR 1 baseline 0.87 at batch 1), a
  per-phase prefill/decode breakdown from the engine's batched-prefill
  stats, and best-of-N timing (single-shot numbers on a shared CPU were
  ±30% noise).
* ``mlp_bound_analysis`` — the Eyexam-style analytic model (DESIGN.md §9)
  of *why* two-call lost, written to BENCH_sparse_decode.json["analytic"].

    PYTHONPATH=src python benchmarks/sparse_decode.py [--smoke] [--no-engine]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import time
from typing import Dict, List

import numpy as np

from repro.configs import get_config
from repro.core import eyexam
from repro.core import plan as plan_lib
from repro.models import decoding

SPARSITIES = (0.5, 0.75, 0.9)
# analytic constants live with the ServePlan roofline (core.plan) so the
# plan's MLP rationale and this benchmark are the same numbers
BCSC_OVERHEAD = plan_lib.BCSC_OVERHEAD
KERNEL_LAUNCH_S = plan_lib.KERNEL_LAUNCH_S
BENCH_JSON = "BENCH_sparse_decode.json"
PR1_E2E_RATIO_B1 = 0.87  # PR 1's recorded batch-1 sparse/dense tokens/sec
ID_BYTES = 8             # row_id + col_id int32 per payload block


def decode_regimes(dryrun_dir: str = "results/dryrun_opt") -> Dict:
    out: Dict = {}
    for f in sorted(glob.glob(os.path.join(dryrun_dir,
                                           "*decode_32k__16x16*"))):
        r = json.load(open(f))
        if r["status"] != "ok":
            continue
        cfg = get_config(r["arch"])
        chips = r["chips"]
        # ANALYTIC decode stream model (the measured term stays conservative
        # on the CPU proxy — scan-carry cache rewrites that TPU aliasing
        # elides; see EXPERIMENTS.md D1). Per chip, per decode step:
        #   weights (active, bf16) + full KV/state-cache read.
        w_bytes = cfg.param_count(active_only=True) * 2 / chips
        cache = decoding.abstract_cache(cfg, 128, 32768)
        import jax
        c_bytes = sum(l.size * l.dtype.itemsize
                      for l in jax.tree.leaves(cache)) / chips
        t_w = w_bytes / eyexam.HBM_BW
        t_c = c_bytes / eyexam.HBM_BW
        t128 = t_w + t_c                      # batch-128 step
        rows: Dict = {
            "t_analytic_128_ms": t128 * 1e3,
            "cache_share": t_c / t128,
            "int8_cache_speedup": t128 / (t_w + t_c / 2),
        }
        # batch-1 regime (one slot): weights dominate; BCSC pays directly
        t1 = t_w + t_c / 128
        for sp in SPARSITIES:
            t1_sp = t_w * (1 - sp) * BCSC_OVERHEAD + t_c / 128
            rows[f"b1_bcsc_speedup_{sp:.2f}"] = t1 / t1_sp
        out[r["arch"]] = rows
    return out


# ------------------------------------------------------- ISSUE 1: fast path
def kernel_proxy(sparsities=SPARSITIES + (0.7,), K: int = 256, N: int = 512,
                 block: int = 16) -> Dict:
    """Batch-1 MLP projection: dense rs_matmul grid steps vs bcsc_gemv nnzb.

    Grid steps are the interpret-mode cost proxy (each step is one MXU-tile
    visit); both sides are normalized to the same (bk, bn) tiles so the ratio
    is exactly the structural-skip factor the paper's Sparse PE claims (§IV).
    """
    import jax.numpy as jnp
    from repro.core import sparsity as sp
    from repro.kernels import ops

    rng = np.random.default_rng(0)
    w = rng.standard_normal((K, N)).astype(np.float32)
    dense_blocks = (K // block) * (N // block)
    out: Dict = {"shape": [K, N], "block": block,
                 "dense_grid_steps": dense_blocks}
    for s in sorted(sparsities):
        ws = np.asarray(sp.block_magnitude_prune(jnp.asarray(w), s,
                                                 block, block))
        m = sp.bcsc_encode(ws, block, block)
        blocks, _, _, _ = ops.prepare_bcsc(m)
        steps = int(blocks.shape[0])
        out[f"sparsity_{s:.2f}"] = {
            "gemv_grid_steps": steps,
            "speedup_vs_dense": dense_blocks / max(steps, 1),
        }
    return out


# ------------------------------------------------- shared: pruned + packed
def _pruned_packed(arch: str, sparsity: float, block: int = 16):
    import jax
    import jax.numpy as jnp
    from repro.core import sparsity as sp
    from repro.models import transformer as tfm
    from repro.serve import sparse as sps

    cfg = get_config(arch)
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    for slot in params.get("blocks", {}):
        mlp = params["blocks"][slot].get("mlp")
        if mlp:
            for nm in list(mlp):
                w = mlp[nm]
                mlp[nm] = jnp.stack([
                    sp.block_magnitude_prune(w[l], sparsity, block, block)
                    for l in range(w.shape[0])])
    packed, stats = sps.sparsify_mlp_params(params, cfg, sparsity=0.0)
    return cfg, params, packed, stats


# --------------------------------- ISSUE 2: fused megakernel vs two-call
def mlp_proxy(arch: str = "qwen2.5-3b-reduced", sparsity: float = 0.75,
              block: int = 16, bm: int = 8, stats: Dict = None) -> Dict:
    """Wall-clock-free cost model: fused bcsc_mlp vs the PR 1 two-call path.

    Counts, per decode token (M = bm activation rows) over every packed MLP
    layer of the model:

    * grid steps — sequential grid steps the kernel actually executes (the
      pipeline/prologue overhead proxy). Two-call visits one payload block
      per step and walks the full padded stack capacity. The megakernel's
      unrolled variant runs ONE step per m-tile; its gridded variant runs
      every capacity chunk step (a skipped chunk still spins its step — only
      its DMA and MACs are elided, which block visits/bytes capture).
    * work chunks — chunk-level units doing real DMA+MACs: capacity chunks
      for the unrolled variant (pads are masked, not skipped), ceil(real/C)
      for the gridded variant (whole pad chunks skipped).
    * block visits — payload blocks DMA'd from HBM. The megakernel's skip is
      chunk-granular, so its waste is < C blocks per segment vs the two-call
      path's full pad-to-densest-layer capacity.
    * hbm bytes — weight payload + index vectors + activations in/out
      **including the hidden-activation round-trip** (g/u written fp32, read
      for the gate product, h written bf16, re-read by the down projection)
      that exists only in the two-call path: the megakernel holds the hidden
      in VMEM scratch from first MAC to final drain.
    """
    from repro.kernels import bcsc_mlp as bmlp

    if stats is None:
        cfg, _, _, stats = _pruned_packed(arch, sparsity, block)
    else:
        cfg = get_config(arch)
    bb = block * block
    w_byte = 2                                   # bf16 payload (pack dtype)
    d = cfg.d_model
    ff = cfg.dense_d_ff if (cfg.moe and cfg.dense_d_ff) else cfg.d_ff
    gated = cfg.mlp_gated

    two = {"grid_steps": 0, "block_visits": 0, "hbm_bytes": 0,
           "kernel_launches": 0}
    fused = {"grid_steps": 0, "work_chunks": 0, "block_visits": 0,
             "hbm_bytes": 0, "kernel_launches": 0}
    weights = stats["weights"]
    names = list(weights)
    # mixed-density guard (ROADMAP latent bug): sparsify_mlp_params routes a
    # weight dense in one layer group and packed in another, so the per-name
    # "real"/"padded" lists can have UNEQUAL lengths — indexing them
    # uniformly was an IndexError. Layers where a projection is missing
    # count only the projections that were actually packed there.
    n_layers = max((len(weights[nm]["real"]) for nm in names), default=0)
    mixed_density = len({len(weights[nm]["real"]) for nm in names}) > 1
    for li in range(n_layers):
        seg = []                        # (real, padded, C) per projection
        for nm in names:
            w = weights[nm]
            if li >= len(w["real"]):
                continue                # dense in this layer group: no pack
            P = w["padded"][li]
            seg.append((w["real"][li], P, bmlp._pick_chunk(P)))
        if not seg:
            continue
        n_chunks = sum(p // c for _, p, c in seg)
        unrolled = n_chunks <= bmlp.UNROLL_CHUNKS_MAX

        # ---- two-call: one kernel per projection, 1 block per grid step
        two["kernel_launches"] += len(seg)
        for real, P, _ in seg:
            two["grid_steps"] += P
            two["block_visits"] += P
            two["hbm_bytes"] += P * (bb * w_byte + ID_BYTES)
        # activations: x read per up kernel, h read by the down kernel,
        # plus the hidden round-trip between the kernels
        ups = 2 if gated else 1
        two["hbm_bytes"] += ups * bm * d * 2          # x in (bf16) per up
        two["hbm_bytes"] += ups * bm * ff * 4         # g/u out (fp32)
        if gated:
            two["hbm_bytes"] += 2 * bm * ff * 4       # g,u re-read for gate
        two["hbm_bytes"] += bm * ff * 2               # h written bf16
        two["hbm_bytes"] += bm * ff * 2               # h read by down kernel
        two["hbm_bytes"] += bm * d * 4                # down out (fp32)

        # ---- fused megakernel: one launch, chunked walk, VMEM hidden
        fused["kernel_launches"] += 1
        fused["grid_steps"] += 1 if unrolled else n_chunks
        for real, P, C in seg:
            if unrolled:
                chunks = P // C          # whole (small) payload resident
            else:
                chunks = max(-(-real // C), 1)        # ceil: ragged skip
            fused["work_chunks"] += chunks
            fused["block_visits"] += chunks * C
            fused["hbm_bytes"] += chunks * C * (bb * w_byte + ID_BYTES)
        fused["hbm_bytes"] += bm * d * 2              # x in, VMEM-resident
        fused["hbm_bytes"] += bm * d * 4              # final out (fp32)

    return {
        "arch": arch, "sparsity": sparsity, "bm": bm,
        "mixed_density": mixed_density,
        "block_density": stats.get("block_density"),
        "packing_efficiency": stats.get("packing_efficiency"),
        "per_weight_packing": {
            nm: {"real": w["real"], "padded": w["padded"],
                 "packing_efficiency": w["packing_efficiency"]}
            for nm, w in weights.items()},
        "two_call": two,
        "fused": fused,
        "ratios": {
            "grid_steps": two["grid_steps"] / max(fused["grid_steps"], 1),
            "block_visits": (two["block_visits"] /
                             max(fused["block_visits"], 1)),
            "hbm_bytes": two["hbm_bytes"] / max(fused["hbm_bytes"], 1),
        },
    }


def mlp_bound_analysis(arch: str = "qwen2.5-3b", sparsity: float = 0.75,
                       packing_efficiency: float = 0.93) -> Dict:
    """Eyexam-style bound shift (paper Appendix A; DESIGN.md §9).

    Why PR 1's two-call sparse path lost end-to-end at batch 1 even though
    its kernels won the grid-step proxy: the decode-step MLP time is

        t = t_weight_stream + t_hidden_roundtrip + n_launch · t_launch

    Sparsity only shrinks the first term. The two-call path *adds* the second
    term (the (bm × d_ff) hidden crosses HBM four times: fp32 out ×2, gate
    re-read, bf16 write + re-read) and triples the third — at full scale the
    hidden round-trip is small next to weights, but the launch term is pure
    overhead, and on the CPU interpret proxy (where per-launch cost is ~100×
    a TPU launch) it dominated, which is exactly the 0.87 ratio recorded in
    PR 1. The megakernel removes both added terms, so the bound returns to
    the weight stream — the only term sparsity can shrink.

    The model itself lives in ``core.plan.mlp_roofline`` — it is the MLP
    decision's rationale inside every resolved ServePlan, and delegating
    keeps this benchmark and ``plan.explain()`` the same numbers by
    construction (tests/test_plan.py asserts it). This wrapper keeps the
    benchmark-JSON schema.
    """
    out = plan_lib.mlp_roofline(get_config(arch), sparsity=sparsity,
                                packing_efficiency=packing_efficiency)
    return {"arch": arch, **out}


# ---------------------------------- ISSUE 3: paged KV + continuous batching
def paged_proxy(arch: str = "qwen2.5-3b-reduced", rows: int = 8,
                cache_len: int = 512, page_size: int = 64,
                mean_occupancy: float = 0.5, seed: int = 0) -> Dict:
    """Wall-clock-free paged-vs-dense cost model (perf_guard gates these).

    * **HBM bytes** — real cache allocations via jax.eval_shape: the dense
      (rows × cache_len) slot cache vs the paged layout provisioned for the
      expected occupancy (pages covering each row's page-rounded length at
      ``mean_occupancy``). Paged must be strictly smaller — that is the
      entire point of block-table indirection.
    * **grid steps** — the paged decode kernel does real work (DMA + MACs)
      on exactly ceil(len/ps) steps per row (the pl.when skip,
      kernels.paged_attention.work_steps); the padded (rows × max_pages)
      grid and the dense-slot equivalent are reported for the skip ratio.
    """
    from repro.core import dataflow
    from repro.kernels.paged_attention import work_steps
    from repro.serve import kvcache

    cfg = get_config(arch)
    rng = np.random.default_rng(seed)
    # ragged lengths with the target mean occupancy (clamped into range)
    lengths = np.clip(rng.normal(mean_occupancy * cache_len,
                                 0.5 * mean_occupancy * cache_len,
                                 rows).astype(int), 1, cache_len).tolist()
    # ceil(len/ps) per row from core.dataflow — the spec-side bound, computed
    # independently of the kernel module so the gate is cross-sourced
    ceil_pages = sum(dataflow.pages_for(n, page_size) for n in lengths)
    dense_bytes = kvcache.cache_bytes(cfg, rows, cache_len)
    paged_bytes = kvcache.paged_cache_bytes(cfg, rows, cache_len, ceil_pages,
                                            page_size)
    max_pages = dataflow.pages_for(cache_len, page_size)
    return {
        "arch": arch, "rows": rows, "cache_len": cache_len,
        "page_size": page_size, "mean_occupancy": mean_occupancy,
        "lengths": lengths,
        "dense_slot_bytes": dense_bytes,
        "paged_bytes": paged_bytes,
        "bytes_ratio": dense_bytes / max(paged_bytes, 1),
        # the kernel's own skip bound (kernels.paged_attention.row_work_steps,
        # the expression its pl.when evaluates) vs. the spec bound above
        "work_steps": work_steps(lengths, page_size),
        "ceil_pages": ceil_pages,
        "padded_grid_steps": rows * max_pages,
        "tokens_resident_paged": dataflow.paged_kv_tokens(lengths, page_size),
        "tokens_resident_dense": dataflow.dense_kv_tokens(rows, cache_len),
    }


def _poisson_arrivals(n: int, mean_gap: float, rng) -> List[float]:
    gaps = rng.exponential(mean_gap, n)
    return np.cumsum(gaps).tolist()


def arrival_benchmark(arch: str = "qwen2.5-3b-reduced", rows: int = 3,
                      n_requests: int = 9, cache_len: int = 48,
                      page_size: int = 8, sync_every: int = 4,
                      mean_gap: float = 3.0, seed: int = 0) -> Dict:
    """Poisson-arrival sweep: continuous batching (paged scheduler) vs the
    drain-the-chunk baseline, at low and high request-length variance.

    The baseline is classic static batching: admit a cohort of ``rows``
    requests in arrival order, wait for the *last* cohort member to arrive,
    run the cohort to full completion (DecodeEngine), then admit the next —
    freed slots idle until the cohort drains. The scheduler admits/evicts at
    every sync boundary instead, and its page pool is provisioned at half
    the dense-slot footprint. Both sides are measured on the deterministic
    virtual clock (1 unit = 1 decode step; arrival gaps in the same unit) so
    the goodput/latency comparison is CI-stable; wall seconds are recorded
    alongside but never gated. Generation lengths are budget-bound
    (eos_id=-1), so token counts — and the whole comparison — are exact.
    """
    import jax
    from repro.core import dataflow
    from repro.models import transformer as tfm
    from repro.serve.engine import DecodeEngine, Request
    from repro.serve.kvcache import cache_bytes, paged_cache_bytes
    from repro.serve.scheduler import (ContinuousBatchingScheduler,
                                       StreamRequest)

    cfg = get_config(arch)
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(seed)
    prompt = [5, 6, 7, 8]
    arrivals = _poisson_arrivals(n_requests, mean_gap, rng)
    # same mean generation length, ~9x the variance: the regime where
    # drain-the-chunk strands slots behind the longest cohort member
    cases = {
        "low_variance": [6 if i % 2 else 10 for i in range(n_requests)],
        "high_variance": [2 if i % 2 else 14 for i in range(n_requests)],
    }
    num_pages = (rows * dataflow.pages_for(cache_len, page_size)) // 2

    out: Dict = {
        "arch": arch, "rows": rows, "n_requests": n_requests,
        "cache_len": cache_len, "page_size": page_size,
        "sync_every": sync_every, "mean_gap": mean_gap,
        "arrivals": [round(a, 2) for a in arrivals],
        "memory": {
            # cache side only: this benchmark serves DENSE params (packing
            # would slow every interpret-mode step for no scheduling signal);
            # the weight-stream side (sparse.packed_bytes) is reported by
            # decode_benchmark, which actually serves packed params
            "dense_cache_bytes": cache_bytes(cfg, rows, cache_len),
            "paged_cache_bytes": paged_cache_bytes(
                cfg, rows, cache_len, num_pages, page_size),
        },
        "cases": {},
    }
    for name, max_news in cases.items():
        row: Dict = {"max_new": max_news,
                     "length_variance": float(np.var(max_news))}

        # ---- continuous batching: paged scheduler on the virtual clock ----
        # engines run plan-driven: dispatch is resolved once by core.plan
        sch = ContinuousBatchingScheduler(
            cfg, params, plan_lib.plan_for_scheduler(
                cfg, rows=rows, cache_len=cache_len, page_size=page_size,
                num_pages=num_pages, attn_path="paged",
                sync_every=sync_every),
            eos_id=-1)
        reqs = [StreamRequest(i, prompt, mn, arrival=arrivals[i])
                for i, mn in enumerate(max_news)]
        t0 = time.perf_counter()
        done = sch.run(reqs)
        wall = time.perf_counter() - t0
        toks = sum(len(r.out) for r in done)
        lat = [r.finished_at - r.arrival for r in done]
        makespan = sch.phase_stats["clock_steps"]
        row["scheduler"] = {
            "tokens": toks,
            "makespan_steps": makespan,
            "goodput_tokens_per_step": toks / max(makespan, 1e-9),
            "latency_p50_steps": float(np.percentile(lat, 50)),
            "latency_p99_steps": float(np.percentile(lat, 99)),
            "preemptions": sch.phase_stats["preemptions"],
            "wall_s": wall,
            "pages_peak": sch.phase_stats.get("pages_peak"),
        }

        # ---- drain-the-chunk baseline: static cohorts of `rows` ----------
        eng = DecodeEngine(cfg, params, plan_lib.plan_for_engine(
            cfg, slots=rows, cache_len=cache_len, sync_every=sync_every),
            eos_id=-1)
        clock, lat_d, toks_d, wall_d = 0.0, [], 0, 0.0
        order = sorted(range(n_requests), key=lambda i: arrivals[i])
        for c0 in range(0, n_requests, rows):
            cohort = order[c0:c0 + rows]
            start = max(clock, max(arrivals[i] for i in cohort))
            t0 = time.perf_counter()
            cdone = eng.run([Request(i, prompt, max_news[i]) for i in cohort])
            wall_d += time.perf_counter() - t0
            steps = eng.phase_stats["decode_chunks"] * sync_every
            clock = start + steps
            toks_d += sum(len(r.out) for r in cdone)
            lat_d += [clock - arrivals[r.rid] for r in cdone]
        row["drain"] = {
            "tokens": toks_d,
            "makespan_steps": clock,
            "goodput_tokens_per_step": toks_d / max(clock, 1e-9),
            "latency_p50_steps": float(np.percentile(lat_d, 50)),
            "latency_p99_steps": float(np.percentile(lat_d, 99)),
            "wall_s": wall_d,
        }
        row["goodput_ratio"] = (
            row["scheduler"]["goodput_tokens_per_step"] /
            max(row["drain"]["goodput_tokens_per_step"], 1e-9))
        out["cases"][name] = row
    lv = out["cases"]["low_variance"]["length_variance"]
    hv = out["cases"]["high_variance"]["length_variance"]
    out["variance_ratio"] = hv / max(lv, 1e-9)
    out["continuous_wins_at_high_variance"] = (
        out["cases"]["high_variance"]["goodput_ratio"] > 1.0)
    return out


# -------------------------------- ISSUE 4: shared-prefix arrival sweep
def shared_prefix_benchmark(arch: str = "qwen2.5-3b-reduced", rows: int = 3,
                            n_requests: int = 6, cache_len: int = 48,
                            page_size: int = 8, sync_every: int = 4,
                            prefix_len: int = 16, max_new: int = 6,
                            mean_gap: float = 2.0, seed: int = 0) -> Dict:
    """CoW prefix sharing under Poisson arrivals: the same request stream
    served with sharing ON vs OFF at a page pool deliberately too small for
    unshared admission to keep every row busy.

    Gated claims (scripts/perf_guard.py):
    * sharing admits strictly MORE concurrent requests at the same pool size
      (peak_live_rows) and peaks at strictly fewer distinct pages;
    * outputs are identical — sharing is a pure memory win;
    * the page-native prefill path allocates no dense (B, cache_len)
      KV transient: its per-layer buffer is the (B, tier) projection output
      itself (byte accounting below, tier << cache_len);
    * int8 KV pages record their quantized-vs-fp byte ratio.
    """
    import jax
    from repro.core import dataflow
    from repro.models import transformer as tfm
    from repro.serve import kvcache
    from repro.serve.engine import length_tier
    from repro.serve.scheduler import (ContinuousBatchingScheduler,
                                       StreamRequest)

    cfg = get_config(arch)
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(seed)
    arrivals = _poisson_arrivals(n_requests, mean_gap, rng)
    prefix = [5 + (i % 90) for i in range(prefix_len)]
    prompts = [prefix + [2 + i, 3 + i] for i in range(n_requests)]
    plen = len(prompts[0])
    # pool sized so unshared admission cannot hold `rows` concurrent
    # requests at their final lengths, but shared admission can
    per_req = dataflow.pages_for(plen + max_new, page_size)
    shared_pages = dataflow.pages_for(prefix_len, page_size)
    num_pages = per_req + (rows - 1) * (per_req - shared_pages) \
        + shared_pages // 2

    def run(share: bool) -> Dict:
        sch = ContinuousBatchingScheduler(
            cfg, params, plan_lib.plan_for_scheduler(
                cfg, rows=rows, cache_len=cache_len, page_size=page_size,
                num_pages=num_pages, attn_path="paged", share_prefix=share,
                sync_every=sync_every),
            eos_id=-1)
        reqs = [StreamRequest(i, prompts[i], max_new, arrival=arrivals[i])
                for i in range(n_requests)]
        t0 = time.perf_counter()
        done = sch.run(reqs)
        wall = time.perf_counter() - t0
        toks = sum(len(r.out) for r in done)
        lat = [r.finished_at - r.arrival for r in done]
        makespan = sch.phase_stats["clock_steps"]
        return {
            "outputs": {r.rid: r.out for r in done},
            "tokens": toks,
            "makespan_steps": makespan,
            "goodput_tokens_per_step": toks / max(makespan, 1e-9),
            "latency_p50_steps": float(np.percentile(lat, 50)),
            "latency_p99_steps": float(np.percentile(lat, 99)),
            "peak_live_rows": sch.phase_stats["peak_live_rows"],
            "preemptions": sch.phase_stats["preemptions"],
            "cow_copies": sch.phase_stats["cow_copies"],
            "shared_tokens_admitted":
                sch.phase_stats["shared_tokens_admitted"],
            "pages_peak": sch.phase_stats["pages_peak"],
            "admission_wait_p99_steps": float(np.percentile(
                [r.admitted_at - r.arrival for r in done], 99)),
            "wall_s": wall,
        }

    shared = run(True)
    unshared = run(False)
    outputs_identical = shared.pop("outputs") == unshared.pop("outputs")

    # ---- prefill transient accounting: scatter path vs page-native ----
    tier = length_tier(plen, False, cache_len)
    n_glob = kvcache.num_global_layers(cfg)
    t_scatter = dataflow.prefill_kv_transient_bytes(
        rows, cache_len, cfg.num_kv_heads, cfg.head_dim, n_glob)
    t_paged = dataflow.prefill_kv_transient_bytes(
        rows, tier, cfg.num_kv_heads, cfg.head_dim, n_glob)

    return {
        "arch": arch, "rows": rows, "n_requests": n_requests,
        "cache_len": cache_len, "page_size": page_size,
        "prefix_len": prefix_len, "max_new": max_new,
        "num_pages": num_pages,
        "arrivals": [round(a, 2) for a in arrivals],
        "shared": shared,
        "unshared": unshared,
        "outputs_identical": outputs_identical,
        "concurrency_gain": (shared["peak_live_rows"]
                             - unshared["peak_live_rows"]),
        "goodput_ratio": (shared["goodput_tokens_per_step"] /
                          max(unshared["goodput_tokens_per_step"], 1e-9)),
        "prefill_transient": {
            "tier": tier,
            "scatter_path_bytes": t_scatter,       # PR 3: (B, cache_len) KV
            "paged_path_bytes": t_paged,           # now: the (B, tier) proj
            "bytes_saved": t_scatter - t_paged,
        },
        "kv_quant": _kv_quant_ratio(cfg, rows, cache_len, num_pages,
                                    page_size),
    }


# ------------------------------- ISSUE 6: overload + injected-fault sweep
def chaos_overload_benchmark(arch: str = "qwen2.5-3b-reduced", rows: int = 3,
                             n_requests: int = 12, cache_len: int = 48,
                             page_size: int = 4, sync_every: int = 4,
                             mean_gap: float = 0.5, seed: int = 0) -> Dict:
    """Overloaded Poisson stream through the serving guard, three ways:

    * ``shed_only`` — the degradation ladder restricted to its last rung:
      admission control sheds arrivals above the pressure threshold.
    * ``ladder``    — the full plan-authorized ladder (int8 pool
      requantization -> clamp max_new -> shed): graceful degradation should
      deliver at least the shed-only goodput while shedding no more.
    * ``faulted``   — shed_only again under a seeded ChaosConfig (spurious
      page-ensure failures, a transient step fault, one NaN poisoning):
      every request must still reach a terminal outcome, the pool must audit
      clean after every sync window (audit_every_sync raises otherwise), and
      every request that completes ``ok`` in both the faulted and the
      fault-free run must produce bit-identical tokens (greedy decode,
      pre-dispatch injection).

    Everything is measured on the deterministic virtual step clock, so
    perf_guard can gate shed rate and degraded goodput without wall-clock
    noise. Goodput counts only tokens of requests that resolved ``ok`` —
    shed/expired/failed work is not goodput by definition.
    """
    import jax
    from repro.core import dataflow
    from repro.models import transformer as tfm
    from repro.serve.chaos import ChaosConfig
    from repro.serve.guard import GuardConfig
    from repro.serve.scheduler import (ContinuousBatchingScheduler,
                                       StreamRequest)

    cfg = get_config(arch)
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(seed)
    prompt = [5, 6, 7, 8]
    arrivals = _poisson_arrivals(n_requests, mean_gap, rng)
    max_news = [8 if i % 2 else 14 for i in range(n_requests)]
    # deliberately under-provisioned: 3 concurrent long requests need 15
    # pages, the pool holds 8 — pressure is the point of this sweep
    num_pages = 8
    assert num_pages < rows * dataflow.pages_for(
        len(prompt) + max(max_news), page_size)
    plan = plan_lib.plan_for_scheduler(
        cfg, rows=rows, cache_len=cache_len, page_size=page_size,
        num_pages=num_pages, attn_path="paged", sync_every=sync_every)
    guards = {
        "shed_only": GuardConfig(degrade_rungs=("shed",), shed_pressure=0.6,
                                 audit_every_sync=True),
        "ladder": GuardConfig(int8_pressure=0.45, clamp_pressure=0.6,
                              shed_pressure=0.8, clamp_max_new=4,
                              audit_every_sync=True),
    }
    # NaN targets rid 0: the longest early request, reliably still resident
    # at chunk 2 — its quarantine (outcome ``failed``) is part of the sweep
    chaos = ChaosConfig(seed=seed + 1, ensure_fail_rate=0.2,
                        ensure_fail_max=6, step_fail_chunks=(1,),
                        step_fail_attempts=2, nan_rids={2: (0,)})

    def run(guard, chaos_cfg=None) -> Dict:
        sch = ContinuousBatchingScheduler(cfg, params, plan, eos_id=-1,
                                          guard=guard)
        reqs = [StreamRequest(i, list(prompt), max_news[i],
                              arrival=arrivals[i])
                for i in range(n_requests)]
        t0 = time.perf_counter()
        done = sch.run(reqs, chaos=chaos_cfg)
        wall = time.perf_counter() - t0
        st = sch.phase_stats
        ok_toks = sum(len(r.out) for r in done if r.outcome.ok)
        makespan = st["clock_steps"]
        return {
            "outcomes": st["outcomes"],
            "all_terminal": len(done) == n_requests
            and all(r.outcome is not None for r in done),
            "shed_rate": st["outcomes"]["shed"] / n_requests,
            "ok_tokens": ok_toks,
            "makespan_steps": makespan,
            "goodput_tokens_per_step": ok_toks / max(makespan, 1e-9),
            "clamped_admissions": st["clamped_admissions"],
            "stalled_boundaries": st["stalled_boundaries"],
            "preemptions": st["preemptions"],
            "kv_quant_final": st["kv_quant"],
            "chaos_injected": st.get("chaos_injected"),
            "pool_audit_clean": True,    # audit_every_sync raises otherwise
            "wall_s": wall,
            "_tokens": {r.rid: list(r.out) for r in done if r.outcome.ok},
        }

    out: Dict = {
        "arch": arch, "rows": rows, "n_requests": n_requests,
        "cache_len": cache_len, "page_size": page_size,
        "num_pages": num_pages, "sync_every": sync_every,
        "mean_gap": mean_gap,
        "arrivals": [round(a, 2) for a in arrivals],
        "max_new": max_news,
    }
    shed_only = run(guards["shed_only"])
    ladder = run(guards["ladder"])
    faulted = run(guards["shed_only"], chaos)
    both_ok = set(shed_only["_tokens"]) & set(faulted["_tokens"])
    out["survivors_bit_identical"] = all(
        shed_only["_tokens"][rid] == faulted["_tokens"][rid]
        for rid in both_ok)
    out["survivors_compared"] = len(both_ok)
    for name, row in (("shed_only", shed_only), ("ladder", ladder),
                      ("faulted", faulted)):
        row.pop("_tokens")
        out[name] = row
    out["goodput_vs_shed_only"] = (
        ladder["goodput_tokens_per_step"] /
        max(shed_only["goodput_tokens_per_step"], 1e-9))
    return out


# -------------------------- ISSUE 7: multi-replica failover trace simulator
def replica_failover_benchmark(arch: str = "qwen2.5-3b-reduced",
                               rows: int = 2, n_requests: int = 12,
                               cache_len: int = 48, page_size: int = 4,
                               sync_every: int = 4, replicas: int = 3,
                               kill_step: float = 8.0, mean_gap: float = 1.0,
                               seed: int = 0) -> Dict:
    """Multi-replica trace simulator: the --arrivals Poisson sweep through
    the replica control plane (serve/replica.py), three ways:

    * ``fault_free``  — N replicas, prefix-affinity routing: the goodput
      baseline, plus the CoW page-sharing the router's placement achieves
      on shared-system-prompt traffic.
    * ``no_affinity`` — identical traffic with affinity off (pure
      least-depth placement, the round-robin-equivalent spread): the
      sharing comparison behind the ``router-prefix-affinity`` gate —
      affinity must win strictly, or the placement rule is dead weight.
    * ``killed``      — replica 0 killed mid-sweep at ``kill_step``:
      stranded requests migrate by recompute; every request must still end
      in exactly one terminal outcome, every request that completes ``ok``
      in both runs must produce bit-identical tokens (greedy decode on the
      shared virtual clock), and fleet goodput must hold the
      ``failover-goodput-floor`` (>= 0.9x fault-free with 1 of
      ``replicas`` lost — the recompute tax, not a collapse).

    Goodput counts ok-tokens per virtual step of fleet makespan; all three
    runs are seed-deterministic, so perf_guard gates them wall-clock-free.
    """
    import jax
    from repro.core import dataflow
    from repro.models import transformer as tfm
    from repro.serve.chaos import ReplicaChaosConfig
    from repro.serve.replica import ReplicaSet
    from repro.serve.router import RouterConfig
    from repro.serve.scheduler import StreamRequest

    cfg = get_config(arch)
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(seed)
    arrivals = _poisson_arrivals(n_requests, mean_gap, rng)
    max_news = [6 if i % 2 else 10 for i in range(n_requests)]
    # two distinct system prompts (two full pages each) + a per-request
    # tail, interleaved across arrivals: affinity routing partitions each
    # prompt group onto its home replica for maximal CoW sharing, while
    # depth-based placement interleaves the groups so co-resident requests
    # hold mismatched prefixes — the traffic shape the placement rule
    # exists for
    sys_prompts = [[11, 12, 13, 14, 15, 16, 17, 18],
                   [21, 22, 23, 24, 25, 26, 27, 28]]
    num_pages = rows * dataflow.pages_for(cache_len, page_size)
    plan = plan_lib.plan_for_scheduler(
        cfg, rows=rows, cache_len=cache_len, page_size=page_size,
        num_pages=num_pages, attn_path="paged", sync_every=sync_every)

    def reqs():
        return [StreamRequest(i, sys_prompts[i % 2] + [30 + i], max_news[i],
                              arrival=arrivals[i], tenant="t%d" % (i % 3))
                for i in range(n_requests)]

    def run(affinity: bool = True, chaos=None) -> Dict:
        rs = ReplicaSet(cfg, params, plan, replicas=replicas, eos_id=-1,
                        router=RouterConfig(affinity=affinity))
        t0 = time.perf_counter()
        done = rs.run(reqs(), chaos=chaos)
        wall = time.perf_counter() - t0
        st = rs.phase_stats
        ok_toks = sum(len(r.out) for r in done if r.outcome.ok)
        makespan = st["clock_steps"]
        return {
            "outcomes": st["outcomes"],
            "all_terminal": len(done) == n_requests
            and all(r.outcome is not None for r in done),
            "ok_tokens": ok_toks,
            "makespan_steps": makespan,
            "goodput_tokens_per_step": ok_toks / max(makespan, 1e-9),
            "failovers": st["failovers"],
            "migrated_requests": st["migrated_requests"],
            "shared_tokens_admitted": st["fleet"]["shared_tokens_admitted"],
            "router": st["router"],
            # per-tenant goodput + admission-wait percentiles (ISSUE 8):
            # requests interleave tenants t0/t1/t2, so tail-wait skew
            # between tenants is the per-tenant fairness signal
            "tenants": st["tenants"],
            "wall_s": wall,
            "_tokens": {r.rid: list(r.out) for r in done if r.outcome.ok},
            "_migrated": {r.rid for r in done if r.migrations > 0},
        }

    out: Dict = {
        "arch": arch, "rows": rows, "replicas": replicas,
        "n_requests": n_requests, "cache_len": cache_len,
        "page_size": page_size, "num_pages": num_pages,
        "sync_every": sync_every, "kill_step": kill_step,
        "mean_gap": mean_gap,
        "arrivals": [round(a, 2) for a in arrivals],
        "max_new": max_news,
    }
    fault_free = run(affinity=True)
    no_affinity = run(affinity=False)
    killed = run(affinity=True,
                 chaos=ReplicaChaosConfig(kill_at_step={0: kill_step}))
    # survivors = requests that never migrated AND completed ok in both
    # runs; bit-identity there proves replica loss never perturbs work
    # that stayed on healthy replicas. Migrated requests are compared too
    # (greedy recompute is exact) but reported separately.
    survivors = [rid for rid in
                 set(fault_free["_tokens"]) & set(killed["_tokens"])
                 if rid not in killed["_migrated"]]
    out["survivors_bit_identical"] = all(
        fault_free["_tokens"][rid] == killed["_tokens"][rid]
        for rid in survivors)
    out["survivors_compared"] = len(survivors)
    out["migrated_bit_identical"] = all(
        fault_free["_tokens"][rid] == killed["_tokens"][rid]
        for rid in killed["_migrated"] if rid in fault_free["_tokens"])
    for name, row in (("fault_free", fault_free),
                      ("no_affinity", no_affinity), ("killed", killed)):
        row.pop("_tokens")
        row["migrated_rids"] = sorted(row.pop("_migrated"))
        out[name] = row
    out["failover_goodput_ratio"] = (
        killed["goodput_tokens_per_step"] /
        max(fault_free["goodput_tokens_per_step"], 1e-9))
    out["affinity_sharing_ratio"] = (
        fault_free["shared_tokens_admitted"] /
        max(no_affinity["shared_tokens_admitted"], 1))
    return out


def telemetry_benchmark(arch: str = "qwen2.5-3b-reduced",
                        n_requests: int = 6, cache_len: int = 64,
                        page_size: int = 4, sync_every: int = 4) -> Dict:
    """Observability sweep (ISSUE 8) behind two perf_guard gates:

    * ``trace-deterministic`` — two same-seed chaos runs (allocation
      failures + transient step faults + NaN poisoning) must produce
      identical trace signatures (wall-clock annotations stripped): the
      trace structure is a pure function of the seed, so a diverging trace
      is itself a determinism regression detector.
    * ``plan-drift-clean`` — Eyexam at runtime, both directions: a plan
      resolved from an *accurate* expected_len_dist must yield a clean
      DriftReport, and a plan provisioned for 40-token requests serving
      8-token traffic must emit a report naming the attention (paging)
      decision as CONFIRMED divergent. A detector that never fires is as
      dead as one that always fires.
    """
    import jax
    from repro.models import transformer as tfm
    from repro.serve import LLM
    from repro.serve.chaos import ChaosConfig
    from repro.serve.scheduler import StreamRequest

    cfg = get_config(arch)
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)

    def plan(mean: float):
        return plan_lib.plan_serve(
            cfg, hbm_budget_bytes=1 << 30, expected_batch=3,
            expected_len_dist={"mean": mean, "max": cache_len},
            page_size=page_size, sync_every=sync_every)

    def reqs(max_new: int):
        return [StreamRequest(rid=i, prompt=[3 + i % 4, 5, 7],
                              max_new=max_new, arrival=float(i),
                              tenant="t%d" % (i % 2))
                for i in range(n_requests)]

    def chaos_run():
        llm = LLM(cfg, params, plan(16), eos_id=-1)
        llm.stream(reqs(13), chaos=ChaosConfig(
            seed=7, ensure_fail_rate=0.3, step_fail_chunks=(1,),
            nan_rids={2: (1,)}))
        return llm.telemetry()

    a, b = chaos_run(), chaos_run()
    sig = a.tracer.signature()

    # accurate plan (mean 16 vs measured 3 prompt + 13 generated = 16)
    llm = LLM(cfg, params, plan(16), eos_id=-1)
    llm.stream(reqs(13))
    clean = llm.telemetry().last_drift

    # mispredicted plan: provisioned for mean 40, serving 8-token requests
    llm = LLM(cfg, params, plan(40), eos_id=-1)
    llm.stream(reqs(5))
    drifted = llm.telemetry().last_drift

    return {
        "arch": arch, "n_requests": n_requests, "cache_len": cache_len,
        "page_size": page_size, "sync_every": sync_every,
        "trace_deterministic": sig == b.tracer.signature(),
        "span_count": len(a.tracer.events),
        "span_categories": sorted({e.cat for e in a.tracer.events}),
        "chaos_injected_kinds": sorted(
            {e.args["kind"] for e in a.tracer.events
             if e.name == "chaos_inject"}),
        "clean_drift": clean.summary(),
        "clean_report": clean.render(),
        "forced_drift": drifted.summary(),
        "forced_report": drifted.render(),
        "forced_names_attention": any(
            f.startswith("attention.") for f in
            drifted.summary()["confirmed"]),
    }


def _print_telemetry(tl: Dict) -> None:
    print(f"=== Telemetry sweep ({tl['arch']}, {tl['n_requests']} reqs) ===")
    print(f"  chaos trace deterministic: {tl['trace_deterministic']} "
          f"({tl['span_count']} spans, cats {tl['span_categories']}, "
          f"injected {tl['chaos_injected_kinds']})")
    print(f"  accurate plan drift: {tl['clean_drift']['confirmed'] or 'clean'}"
          f" over {tl['clean_drift']['windows']} windows")
    print(f"  mispredicted plan drift: {tl['forced_drift']['confirmed']} "
          f"(names attention: {tl['forced_names_attention']})")


def _print_replica_failover(rf: Dict) -> None:
    print(f"=== Replica failover sweep ({rf['replicas']} replicas x "
          f"{rf['rows']} rows, {rf['n_requests']} reqs, kill replica 0 @ "
          f"step {rf['kill_step']:g}) ===")
    for name in ("fault_free", "no_affinity", "killed"):
        c = rf[name]
        print(f"  {name:11s}: goodput {c['goodput_tokens_per_step']:.3f} "
              f"tok/step  makespan {c['makespan_steps']:.0f}  "
              f"ok {c['outcomes']['ok']}  failovers {c['failovers']}  "
              f"migrated {c['migrated_requests']}  "
              f"shared_toks {c['shared_tokens_admitted']}")
    print(f"  failover goodput x{rf['failover_goodput_ratio']:.2f} of "
          f"fault-free; survivors bit-identical: "
          f"{rf['survivors_bit_identical']} "
          f"({rf['survivors_compared']} compared, migrated identical: "
          f"{rf['migrated_bit_identical']}); affinity sharing "
          f"x{rf['affinity_sharing_ratio']:.1f} vs no-affinity")
    for tenant, t in rf["fault_free"]["tenants"].items():
        print(f"    tenant {tenant}: ok {t['ok_requests']:.0f}  goodput "
              f"{t['goodput_tokens']:.0f} tok  admission wait p50 "
              f"{t['admission_wait_p50_steps']:.0f} / p99 "
              f"{t['admission_wait_p99_steps']:.0f} steps")


def _print_chaos(ch: Dict) -> None:
    print(f"=== Overload + chaos sweep ({ch['rows']} rows, "
          f"{ch['n_requests']} reqs, {ch['num_pages']} pages) ===")
    for name in ("shed_only", "ladder", "faulted"):
        c = ch[name]
        oc = c["outcomes"]
        print(f"  {name:9s}: goodput {c['goodput_tokens_per_step']:.3f} "
              f"tok/step  shed {oc['shed']}  ok {oc['ok']}  "
              f"failed {oc['failed']}  clamped {c['clamped_admissions']}  "
              f"kv={c['kv_quant_final']}")
    print(f"  ladder/shed_only goodput x{ch['goodput_vs_shed_only']:.2f}; "
          f"faulted survivors bit-identical: "
          f"{ch['survivors_bit_identical']} "
          f"({ch['survivors_compared']} compared)")


def _kv_quant_ratio(cfg, rows, cache_len, num_pages, page_size) -> Dict:
    """Quantized-vs-fp byte accounting for the paged cache (int8 payload +
    per-page scale tables vs bf16) — the recorded ratio the guard checks."""
    from repro.serve import kvcache
    fp_b = kvcache.paged_cache_bytes(cfg, rows, cache_len, num_pages,
                                     page_size, "fp")
    i8_b = kvcache.paged_cache_bytes(cfg, rows, cache_len, num_pages,
                                     page_size, "int8")
    return {"fp_cache_bytes": fp_b, "int8_cache_bytes": i8_b,
            "int8_vs_fp_ratio": i8_b / max(fp_b, 1)}


# ------------------------------ ISSUE 9: speculative decode on CoW pages
def spec_decode_benchmark(arch: str = "qwen2.5-3b-reduced", spec_k: int = 4,
                          max_new: int = 16, cache_len: int = 64,
                          sync_every: int = 4, batches=(1, 4),
                          repeats: int = 3, seed: int = 7) -> Dict:
    """Draft/verify speculation (serve.scheduler spec chunks) vs the
    sequential greedy baseline, batch {1, 4}.

    The gated speedup is measured on the **deterministic dispatch clock**
    (the same convention as the arrivals and chaos sweeps): the baseline
    retires exactly one token per row per decode step, so its dispatch
    count IS its token count, while a speculative round retires the
    accepted-prefix length against one flattened k-position verify. With
    bit-identical outputs (asserted per batch) the ratio

        baseline decode steps / speculative verify rounds

    is the tokens-per-dispatch speedup — CI-stable, wall-clock-free.
    Wall seconds are recorded alongside (best-of-``repeats``) but never
    gated. ``verify_hbm_bytes`` models the price: one round streams the
    weights once but the resident cache ``spec_k`` times, which is why the
    plan only speculates where the weight stream dominates (batch 1).
    """
    import jax
    from repro.models import transformer as tfm
    from repro.serve import kvcache
    from repro.serve.scheduler import (ContinuousBatchingScheduler,
                                       StreamRequest)

    cfg = get_config(arch)
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    # the batch-1 prompt is chosen for a non-degenerate draft (the bigram
    # self-draft on this seed accepts ~half its candidates, not all of them)
    base_prompts = [[9, 8, 7], [5, 6, 7, 8], [3, 1, 4, 1, 5], [2, 7, 1, 8]]
    w_bytes = cfg.param_count(active_only=True) * 2
    out: Dict = {"arch": arch, "spec_k": spec_k, "max_new": max_new,
                 "cache_len": cache_len, "sync_every": sync_every,
                 "repeats": repeats, "alpha_assumed": plan_lib.SPEC_ALPHA,
                 "batches": {}}
    for b in batches:
        prompts = [base_prompts[i % len(base_prompts)] for i in range(b)]
        plans = {k: plan_lib.plan_serve(
            cfg, hbm_budget_bytes=1 << 30, expected_batch=b,
            expected_len_dist={"mean": (max(map(len, prompts)) + max_new),
                               "max": cache_len},
            page_size=8, attn_path="paged", sync_every=sync_every,
            spec_k=k) for k in (0, spec_k)}
        row: Dict = {}
        for name, k in (("baseline", 0), ("spec", spec_k)):
            sch = ContinuousBatchingScheduler(cfg, params, plans[k],
                                              eos_id=-1)
            runs = []
            for rep in range(repeats + 1):       # first run = warmup/compile
                reqs = [StreamRequest(i, list(p), max_new)
                        for i, p in enumerate(prompts)]
                t0 = time.perf_counter()
                done = sch.run(reqs, rng=jax.random.PRNGKey(seed))
                runs.append((time.perf_counter() - t0,
                             dict(sch.phase_stats),
                             {r.rid: r.out for r in done}))
            wall, st, toks = min(runs[1:], key=lambda r: r[0])
            n_tok = sum(len(t) for t in toks.values())
            dispatches = (st["spec_rounds"] if k else st["decode_steps"])
            row[name] = {
                "tokens": n_tok,
                "wall_s": wall,
                "tokens_per_s_wall": n_tok / max(wall, 1e-9),
                "decode_dispatches": dispatches,
                "tokens_per_dispatch": n_tok / max(dispatches, 1),
                "outputs": toks,
            }
            if k:
                drafted = st["spec_drafted_tokens"]
                row[name]["acceptance_rate"] = (
                    st["spec_accepted_tokens"] / max(drafted, 1))
                row[name]["spec_rounds"] = st["spec_rounds"]
                row[name]["spec_drafted_tokens"] = drafted
                row[name]["spec_accepted_tokens"] = st["spec_accepted_tokens"]
        c_bytes = kvcache.cache_bytes(cfg, b, cache_len)
        row["hbm_model"] = {
            # per retired token: baseline streams weights+cache once/token;
            # one spec round streams weights once + cache spec_k times for
            # E[n] = acceptance-run tokens
            "baseline_step_bytes": w_bytes + c_bytes,
            "verify_round_bytes": w_bytes + spec_k * c_bytes,
            "verify_bytes_per_token": (w_bytes + spec_k * c_bytes) /
            max(row["spec"]["tokens_per_dispatch"], 1e-9),
        }
        row["greedy_bit_exact"] = (row["baseline"].pop("outputs")
                                   == row["spec"].pop("outputs"))
        row["speedup_tokens_per_dispatch"] = (
            row["spec"]["tokens_per_dispatch"] /
            max(row["baseline"]["tokens_per_dispatch"], 1e-9))
        row["speedup_wall"] = (row["spec"]["tokens_per_s_wall"] /
                               max(row["baseline"]["tokens_per_s_wall"],
                                   1e-9))
        out["batches"][str(b)] = row
    return out


def shard_proxy_benchmark(cases=(("gemma2-2b-reduced", "tp=2"),
                                 ("mixtral-8x7b-reduced", "ep=4")),
                          max_new: int = 10, seed: int = 7) -> Dict:
    """Mesh-sharded stream() vs single-device (ISSUE 10): per-token
    bit-identity (gated: sharded-outputs-identical), per-device KV pool
    bytes vs the 1/tp ideal (gated: sharded-pool-bytes-per-device), and the
    analytic collective traffic the scheduler counted. Logical mesh — the
    shard-explicit program is the same math on any host, which is exactly
    the property the gate pins."""
    import jax
    from repro.models import transformer as tfm
    from repro.serve.facade import LLM

    out: Dict = {"max_new": max_new, "cases": {}}
    kw = dict(hbm_budget_bytes=1 << 30, expected_batch=3,
              expected_len_dist={"mean": 10, "max": 64}, page_size=4,
              sync_every=4)
    for arch, mesh in cases:
        cfg = get_config(arch)
        params = tfm.init_params(jax.random.PRNGKey(0), cfg)
        reqs = [([5, 7, 11], max_new), ([3, 2, 9, 4], max_new - 2)]
        single = plan_lib.plan_serve(cfg, **kw)
        sharded = plan_lib.plan_serve(cfg, mesh=mesh, **kw)
        o1 = [r.out for r in LLM(cfg, params, single)
              .stream(reqs, rng=jax.random.PRNGKey(seed))]
        llm = LLM(cfg, params, sharded)
        o2 = [r.out for r in llm.stream(reqs, rng=jax.random.PRNGKey(seed))]
        rep = llm.sharding_report()
        snap = llm.telemetry().metrics.snapshot()
        out["cases"][f"{arch}@{mesh}"] = {
            "arch": arch, "mesh": mesh, "tp": sharded.tp, "ep": sharded.ep,
            "devices": sharded.mesh_devices, "paged": sharded.paged,
            "outputs_identical": o1 == o2,
            "tokens": sum(len(t) for t in o2),
            "kv_bytes_single_device": rep["kv_bytes_single_device"],
            "kv_bytes_per_device": rep["kv_bytes_per_device"],
            # page-rounding slack for the pool gate: one page frame's
            # local bytes (the per-device pool is whole frames)
            "page_frame_bytes_per_device": (
                rep["kv_bytes_per_device"] // max(sharded.num_pages, 1)
                if sharded.paged else 0),
            "lockstep_divergence": rep.get("lockstep_divergence", 0),
            "collective_ops": snap.counters["collective_ops"],
            "collective_allgather_bytes":
                snap.counters["collective_allgather_bytes"],
        }
    return out


def _print_shard(sp: Dict) -> None:
    print("=== Mesh-sharded serving vs single-device ===")
    for name, c in sp["cases"].items():
        print(f"  {name}: tp={c['tp']} ep={c['ep']} "
              f"bit-identical: {c['outputs_identical']} "
              f"({c['tokens']} tokens), lockstep divergence "
              f"{c['lockstep_divergence']}")
        if c["paged"]:
            print(f"           pool/device {c['kv_bytes_per_device']:,} B "
                  f"vs single-device {c['kv_bytes_single_device']:,} B "
                  f"(1/{c['tp']} heads)")
        print(f"           collectives: {c['collective_ops']} all-gathers, "
              f"{c['collective_allgather_bytes']:,} B")


def _print_spec(spd: Dict) -> None:
    print(f"=== Speculative decode on CoW pages ({spd['arch']}, "
          f"k={spd['spec_k']}, {spd['max_new']} new tokens) ===")
    for b, row in spd["batches"].items():
        sp = row["spec"]
        print(f"  batch {b}: {row['speedup_tokens_per_dispatch']:.2f}x "
              f"tokens/dispatch ({sp['tokens_per_dispatch']:.2f} vs "
              f"{row['baseline']['tokens_per_dispatch']:.2f}), "
              f"wall x{row['speedup_wall']:.2f}, acceptance "
              f"{sp['acceptance_rate']:.0%} "
              f"({sp['spec_accepted_tokens']}/{sp['spec_drafted_tokens']}), "
              f"bit-exact: {row['greedy_bit_exact']}")
        hm = row["hbm_model"]
        print(f"           verify round {hm['verify_round_bytes']:,} B vs "
              f"step {hm['baseline_step_bytes']:,} B "
              f"({hm['verify_bytes_per_token']:,.0f} B/token)")


# --------------------------------------------------------- engine benchmark
def decode_benchmark(batches=(1, 4, 8), max_new: int = 8,
                     arch: str = "qwen2.5-3b-reduced",
                     sparsity: float = 0.75, sync_every: int = 4,
                     repeats: int = 5, prepacked=None) -> Dict:
    """DecodeEngine tokens/sec, dense vs BCSC-packed MLP weights.

    On this CPU container kernels run interpret=True, so the sparse wall-clock
    is NOT the headline (Python-interpreted kernels); the grid-step/bytes
    proxies (mlp_proxy) carry the perf claim. On TPU the same harness times
    the compiled kernels. host_syncs per generated token is reported as the
    device-residency check (must be << 1). Timing is best-of-``repeats``
    (interleaved warm engines — the min is the standard noise-robust
    estimator on a shared CPU; single-shot runs here vary ±30%); ``phases``
    reports the best run's batched-prefill/decode wall-clock split and pad
    overhead.
    """
    import jax
    from repro.serve.engine import DecodeEngine, Request

    # ``prepacked``: reuse a (cfg, params, packed, stats) tuple from
    # _pruned_packed instead of re-pruning+encoding the whole model
    cfg, params, packed, stats = prepacked or _pruned_packed(arch, sparsity)

    from repro.serve import sparse as sps
    out: Dict = {"arch": arch, "sparsity": sparsity, "max_new": max_new,
                 "block_density": stats.get("block_density"),
                 "packing_efficiency": stats.get("packing_efficiency"),
                 "packed_weight_bytes": sps.packed_bytes(packed),
                 "interpret_mode": jax.default_backend() != "tpu",
                 "repeats": repeats, "batches": {}}
    for b in batches:
        row: Dict = {}
        engines = {}
        for name, p in (("dense", params), ("sparse", packed)):
            eng = DecodeEngine(cfg, p, plan_lib.plan_for_engine(
                cfg, slots=b, cache_len=32, sync_every=sync_every),
                eos_id=-1)
            eng.run([Request(rid=99, prompt=[5, 6, 7, 8], max_new=max_new)
                     for _ in range(b)])          # warmup / compile
            engines[name] = eng
        times: Dict[str, List] = {n: [] for n in engines}
        for _ in range(repeats):
            for name, eng in engines.items():     # interleaved A/B
                reqs = [Request(rid=i, prompt=[5, 6, 7, 8], max_new=max_new)
                        for i in range(b)]
                eng.host_syncs = 0
                t0 = time.perf_counter()
                done = eng.run(reqs)
                times[name].append((time.perf_counter() - t0,
                                    dict(eng.phase_stats), eng.host_syncs))
        for name, eng in engines.items():
            toks = b * max_new
            dt, st, syncs = min(times[name], key=lambda r: r[0])
            row[name] = {
                "tokens_per_s": toks / max(dt, 1e-9),
                "host_syncs_per_token": syncs / max(toks, 1),
                "phases": {
                    "prefill_s": st["prefill_s"],
                    "decode_s": st["decode_s"],
                    "prefill_batches": st["prefill_batches"],
                    "prefill_prompts": st["prefill_prompts"],
                    "prefill_real_tokens": st["prefill_real_tokens"],
                    "prefill_padded_tokens": st["prefill_padded_tokens"],
                },
            }
        row["e2e_ratio"] = (row["sparse"]["tokens_per_s"] /
                            max(row["dense"]["tokens_per_s"], 1e-9))
        out["batches"][str(b)] = row
    if "1" in out["batches"]:
        out["e2e_ratio_b1"] = out["batches"]["1"]["e2e_ratio"]
        out["pr1_baseline_e2e_ratio_b1"] = PR1_E2E_RATIO_B1
        out["improves_pr1_baseline"] = (
            out["e2e_ratio_b1"] > PR1_E2E_RATIO_B1)
    return out


def _print_shared_prefix(sp: Dict) -> None:
    s, u = sp["shared"], sp["unshared"]
    print(f"=== Shared-prefix arrivals: CoW sharing vs unshared "
          f"({sp['rows']} rows, {sp['n_requests']} reqs, "
          f"{sp['prefix_len']}-token prefix, {sp['num_pages']} pages) ===")
    print(f"  shared  : peak {s['peak_live_rows']} rows, "
          f"{s['pages_peak']['pages_used']} pages, "
          f"{s['goodput_tokens_per_step']:.3f} tok/step, "
          f"admit-wait p99 {s['admission_wait_p99_steps']:.0f}, "
          f"{s['cow_copies']} CoW copies")
    print(f"  unshared: peak {u['peak_live_rows']} rows, "
          f"{u['pages_peak']['pages_used']} pages, "
          f"{u['goodput_tokens_per_step']:.3f} tok/step, "
          f"admit-wait p99 {u['admission_wait_p99_steps']:.0f}")
    pt = sp["prefill_transient"]
    print(f"  prefill KV transient: paged {pt['paged_path_bytes']} B "
          f"(tier {pt['tier']}) vs scatter {pt['scatter_path_bytes']} B "
          f"(dense cache_len rows)")
    kq = sp["kv_quant"]
    print(f"  int8 KV pages: {kq['int8_cache_bytes']} B vs fp "
          f"{kq['fp_cache_bytes']} B ({kq['int8_vs_fp_ratio']:.2f}x), "
          f"outputs identical: {sp['outputs_identical']}")


def main(smoke: bool = False, engine: bool = True, repeats: int = None,
         arrivals: bool = True) -> Dict:
    sparsity = 0.75
    prepacked = _pruned_packed("qwen2.5-3b-reduced", sparsity)
    stats = prepacked[3]
    res: Dict = {
        "analytic": {
            "mlp_megakernel": mlp_bound_analysis(
                packing_efficiency=stats.get("packing_efficiency", 0.93)),
            "decode_regimes": decode_regimes(),
        },
        "kernel_proxy": kernel_proxy(),
        "mlp_proxy": mlp_proxy(sparsity=sparsity, stats=stats),
        "paged": paged_proxy(),
        # resolved ServePlans for the seed configs at the canonical snapshot
        # inputs — perf_guard's `plan-snapshot-stable` gate compares these
        # against scripts/golden_plans.json (silent dispatch drift fails CI)
        "plans": {arch: plan_lib.snapshot_plan(arch).as_dict()
                  for arch in plan_lib.SNAPSHOT_CONFIGS},
        # mesh-sharded plans (ISSUE 10) at the canonical 2 mesh shapes —
        # perf_guard's `sharded-plan-snapshot-stable` gate compares these
        # against golden_plans.json["__sharded__"]
        "sharded_plans": {
            arch: {mesh: plan_lib.snapshot_sharded_plan(arch, mesh)
                   .as_dict()
                   for mesh in plan_lib.SHARDED_SNAPSHOT_MESHES}
            for arch in plan_lib.SHARDED_SNAPSHOT_CONFIGS},
    }
    if engine:
        # seeded + dispatch-clock metrics: the spec-decode gates are
        # wall-clock-free like every other scheduler sweep
        res["spec_proxy"] = spec_decode_benchmark(
            repeats=2 if smoke else 3)
        # seeded, wall-clock-free: the sharded bit-identity and pool gates
        res["shard_proxy"] = shard_proxy_benchmark()
        res["decode"] = decode_benchmark(
            batches=(1,) if smoke else (1, 4, 8),
            max_new=8,
            sparsity=sparsity,
            repeats=repeats or (5 if smoke else 7),
            prepacked=prepacked)
    if engine and arrivals:
        res["arrivals"] = arrival_benchmark(
            n_requests=6 if smoke else 9)
        res["shared_prefix"] = shared_prefix_benchmark(
            n_requests=4 if smoke else 6)
        # not scaled down in smoke: the shed/goodput gates need the exact
        # overload profile the guard thresholds were tuned against
        res["chaos"] = chaos_overload_benchmark()
        # likewise exact: the failover/affinity gates compare seeded runs
        res["replica_failover"] = replica_failover_benchmark()
        # seeded, wall-clock-free: the trace-determinism and drift gates
        res["telemetry"] = telemetry_benchmark()

    kp = res["kernel_proxy"]
    print("=== Batch-1 BCSC GEMV vs dense RS grid steps "
          f"({kp['shape'][0]}x{kp['shape'][1]}, {kp['block']}-blocks) ===")
    print(f"dense grid steps: {kp['dense_grid_steps']}")
    for k in sorted(k for k in kp if k.startswith("sparsity_")):
        r = kp[k]
        print(f"  {k[9:]:>5s} block-sparse: {r['gemv_grid_steps']:5d} steps "
              f"-> {r['speedup_vs_dense']:.2f}x fewer")

    mp = res["mlp_proxy"]
    print(f"=== Fused bcsc_mlp vs two-call @ {mp['sparsity']:.0%} sparsity "
          f"({mp['arch']}) ===")
    for side in ("two_call", "fused"):
        r = mp[side]
        wc = f"  {r['work_chunks']:4d} work chunks" if "work_chunks" in r \
            else ""
        print(f"  {side:9s}: {r['grid_steps']:5d} grid steps  "
              f"{r['block_visits']:5d} block visits  "
              f"{r['hbm_bytes']:8d} HBM bytes  "
              f"{r['kernel_launches']:3d} launches{wc}")
    rr = mp["ratios"]
    print(f"  fused wins: {rr['grid_steps']:.2f}x steps, "
          f"{rr['hbm_bytes']:.2f}x bytes "
          f"(packing efficiency {mp['packing_efficiency']:.2f})")

    if engine:
        d = res["decode"]
        mode = "interpret (proxy only)" if d["interpret_mode"] else "compiled"
        print(f"=== DecodeEngine tokens/sec [{mode}] "
              f"{d['arch']} @ {d['sparsity']:.0%} sparsity ===")
        for b, row in d["batches"].items():
            ph = row["sparse"]["phases"]
            print(f"  batch {b}: dense {row['dense']['tokens_per_s']:8.2f} t/s"
                  f"  sparse {row['sparse']['tokens_per_s']:8.2f} t/s"
                  f"  ratio {row['e2e_ratio']:.3f}"
                  f"  (prefill {ph['prefill_s']*1e3:.1f}ms/"
                  f"{ph['prefill_batches']}b, decode {ph['decode_s']*1e3:.1f}ms,"
                  f" syncs/tok {row['sparse']['host_syncs_per_token']:.3f})")
        if "e2e_ratio_b1" in d:
            verdict = "improves" if d["improves_pr1_baseline"] else "REGRESSES"
            print(f"  batch-1 e2e sparse/dense ratio {d['e2e_ratio_b1']:.3f} "
                  f"{verdict} PR 1 baseline {PR1_E2E_RATIO_B1}")

    pg = res["paged"]
    print(f"=== Paged KV proxy ({pg['arch']}, {pg['rows']} rows x "
          f"{pg['cache_len']} ctx, {pg['page_size']}-token pages, "
          f"{pg['mean_occupancy']:.0%} occupancy) ===")
    print(f"  dense slot {pg['dense_slot_bytes']:9d} B  "
          f"paged {pg['paged_bytes']:9d} B  "
          f"({pg['bytes_ratio']:.2f}x smaller)")
    print(f"  kernel work steps {pg['work_steps']} <= ceil-pages "
          f"{pg['ceil_pages']} (padded grid {pg['padded_grid_steps']})")

    if "arrivals" in res:
        ar = res["arrivals"]
        print(f"=== Poisson arrivals: continuous batching vs drain "
              f"({ar['rows']} rows, {ar['n_requests']} reqs, "
              f"variance x{ar['variance_ratio']:.0f}) ===")
        for name, c in ar["cases"].items():
            s, dr = c["scheduler"], c["drain"]
            print(f"  {name:14s}: sched {s['goodput_tokens_per_step']:.3f} "
                  f"tok/step p50 {s['latency_p50_steps']:.0f} "
                  f"p99 {s['latency_p99_steps']:.0f}"
                  f"  | drain {dr['goodput_tokens_per_step']:.3f} tok/step "
                  f"p50 {dr['latency_p50_steps']:.0f} "
                  f"p99 {dr['latency_p99_steps']:.0f}"
                  f"  -> goodput x{c['goodput_ratio']:.2f}")
        verdict = "beats" if ar["continuous_wins_at_high_variance"] \
            else "LOSES TO"
        print(f"  continuous batching {verdict} drain-the-chunk at high "
              f"length variance")

    if "spec_proxy" in res:
        _print_spec(res["spec_proxy"])

    if "shard_proxy" in res:
        _print_shard(res["shard_proxy"])

    if "shared_prefix" in res:
        _print_shared_prefix(res["shared_prefix"])

    if "chaos" in res:
        _print_chaos(res["chaos"])

    if "replica_failover" in res:
        _print_replica_failover(res["replica_failover"])

    if "telemetry" in res:
        _print_telemetry(res["telemetry"])

    with open(BENCH_JSON, "w") as f:
        json.dump(res, f, indent=2, default=float)
    print(f"wrote {BENCH_JSON}")
    return res


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="batch 1 only (CI)")
    ap.add_argument("--no-engine", action="store_true",
                    help="skip the DecodeEngine wall-clock section")
    ap.add_argument("--no-arrivals", action="store_true",
                    help="skip the Poisson-arrival scheduler-vs-drain sweep")
    ap.add_argument("--arrivals", action="store_true",
                    help="run ONLY the arrival sweep (+paged proxy), merging "
                         "into an existing BENCH json")
    ap.add_argument("--repeats", type=int, default=None,
                    help="timing repeats per engine config (best-of)")
    args = ap.parse_args()
    if args.arrivals:
        res = {}
        if os.path.exists(BENCH_JSON):
            res = json.load(open(BENCH_JSON))
        res["paged"] = paged_proxy()
        res["arrivals"] = arrival_benchmark()
        res["shared_prefix"] = shared_prefix_benchmark()
        res["chaos"] = chaos_overload_benchmark()
        res["replica_failover"] = replica_failover_benchmark()
        res["telemetry"] = telemetry_benchmark()
        with open(BENCH_JSON, "w") as f:
            json.dump(res, f, indent=2, default=float)
        ar = res["arrivals"]
        for name, c in ar["cases"].items():
            print(f"{name}: goodput ratio x{c['goodput_ratio']:.2f} "
                  f"(sched p99 {c['scheduler']['latency_p99_steps']:.0f} vs "
                  f"drain p99 {c['drain']['latency_p99_steps']:.0f} steps)")
        _print_shared_prefix(res["shared_prefix"])
        _print_chaos(res["chaos"])
        print(f"wrote {BENCH_JSON}")
    else:
        main(smoke=args.smoke, engine=not args.no_engine,
             repeats=args.repeats, arrivals=not args.no_arrivals)
