"""Sparse/compressed decode analysis — what actually bounds the decode cells,
and which compression lever (paper §IV) moves each regime.

Measured finding (see run()): at decode_32k's batch of 128 slots the memory
term is **KV-cache streaming** (the whole 32k-token cache is read every
step; weights amortize over the 128 slots — weight-stream share < 1%).
Weight sparsity (BCSC, the paper's Sparse PE) therefore pays at *small
batch*, while at large batch the paper-faithful compression move is applying
the same keep-it-compressed idea to the **cache** (int8 KV ≈ ×2 bytes).
This mirrors the paper's own Table VI shift: compact models (less reuse)
move the bottleneck from compute to delivery, and the right compression
target follows the bottleneck.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict

from repro.configs import get_config
from repro.core import eyexam
from repro.models import decoding

SPARSITIES = (0.5, 0.75, 0.9)
BCSC_OVERHEAD = 1.02     # index-vector bytes per payload byte


def run(dryrun_dir: str = "results/dryrun_opt") -> Dict:
    out: Dict = {}
    for f in sorted(glob.glob(os.path.join(dryrun_dir,
                                           "*decode_32k__16x16*"))):
        r = json.load(open(f))
        if r["status"] != "ok":
            continue
        cfg = get_config(r["arch"])
        chips = r["chips"]
        # ANALYTIC decode stream model (the measured term stays conservative
        # on the CPU proxy — scan-carry cache rewrites that TPU aliasing
        # elides; see EXPERIMENTS.md D1). Per chip, per decode step:
        #   weights (active, bf16) + full KV/state-cache read.
        w_bytes = cfg.param_count(active_only=True) * 2 / chips
        cache = decoding.abstract_cache(cfg, 128, 32768)
        import jax
        c_bytes = sum(l.size * l.dtype.itemsize
                      for l in jax.tree.leaves(cache)) / chips
        t_w = w_bytes / eyexam.HBM_BW
        t_c = c_bytes / eyexam.HBM_BW
        t128 = t_w + t_c                      # batch-128 step
        rows: Dict = {
            "t_analytic_128_ms": t128 * 1e3,
            "cache_share": t_c / t128,
            "int8_cache_speedup": t128 / (t_w + t_c / 2),
        }
        # batch-1 regime (one slot): weights dominate; BCSC pays directly
        t1 = t_w + t_c / 128
        for sp in SPARSITIES:
            t1_sp = t_w * (1 - sp) * BCSC_OVERHEAD + t_c / 128
            rows[f"b1_bcsc_speedup_{sp:.2f}"] = t1 / t1_sp
        out[r["arch"]] = rows
    return out


def main() -> Dict:
    res = run()
    if not res:
        print("no decode records — run the dry-run batch first")
        return {}
    print("=== Decode compression analysis (paper §IV applied per regime) ===")
    print(f"{'arch':28s} {'cache%':>7s} {'int8-KV x':>10s}   "
          f"batch-1 BCSC x @ " +
          "/".join(f"{s:.0%}" for s in SPARSITIES))
    for arch, r in res.items():
        b1 = "/".join(f"{r[f'b1_bcsc_speedup_{s:.2f}']:.2f}"
                      for s in SPARSITIES)
        print(f"{arch:28s} {r['cache_share'] * 100:6.1f}% "
              f"{r['int8_cache_speedup']:10.2f}   {b1}")
    print("(analytic decode stream model; cache% = KV/state-cache share "
          "at batch 128;\n int8-KV x = step speedup from int8 cache; "
          "batch-1 BCSC x = weight-stream speedup\n from block-sparse "
          "weights at one slot — the paper's Sparse-PE regime)")
    return res


if __name__ == "__main__":
    main()
