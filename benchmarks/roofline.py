"""§Roofline table: aggregate the dry-run JSONs (results/dryrun) into the
per-(arch × shape × mesh) three-term roofline report (deliverable g)."""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

DRYRUN_DIR = os.environ.get("DRYRUN_DIR", "results/dryrun")


def load(dryrun_dir: str = DRYRUN_DIR) -> List[Dict]:
    recs = []
    for f in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        recs.append(json.load(open(f)))
    return recs


def table(recs: List[Dict], mesh: str = "16x16") -> List[Dict]:
    rows = []
    for r in recs:
        if r.get("mesh") != mesh:
            continue
        if r["status"] != "ok":
            rows.append({"cell": f"{r['arch']}:{r['shape']}",
                         "status": r["status"],
                         "note": r.get("reason", r.get("error", ""))[:60]})
            continue
        rows.append({
            "cell": f"{r['arch']}:{r['shape']}",
            "status": "ok",
            "t_compute": r["t_compute_s"],
            "t_memory": r["t_memory_s"],
            "t_collective": r["t_collective_s"],
            "bound": r["bound"],
            "useful_ratio": r["useful_flops_ratio"],
            "roofline_frac": r["roofline_fraction"],
            "hbm_gb": r["hbm_per_chip_gb"],
        })
    return rows


def main() -> Dict:
    recs = load()
    if not recs:
        print(f"no dry-run records in {DRYRUN_DIR} — run "
              "scripts/run_dryrun_all.sh first")
        return {}
    out = {}
    for mesh in ("16x16", "2x16x16"):
        rows = table(recs, mesh)
        out[mesh] = rows
        print(f"=== §Roofline ({mesh}, {sum(r['status'] == 'ok' for r in rows)}"
              f"/{len(rows)} ok) ===")
        print(f"{'cell':42s} {'t_comp':>9s} {'t_mem':>9s} {'t_coll':>9s} "
              f"{'bound':>10s} {'useful':>7s} {'roofl%':>7s}")
        for r in rows:
            if r["status"] != "ok":
                print(f"{r['cell']:42s} {r['status']}: {r['note']}")
                continue
            print(f"{r['cell']:42s} {r['t_compute']:9.2e} {r['t_memory']:9.2e} "
                  f"{r['t_collective']:9.2e} {r['bound']:>10s} "
                  f"{r['useful_ratio']:7.3f} {r['roofline_frac'] * 100:6.2f}%")
    return out


if __name__ == "__main__":
    main()
