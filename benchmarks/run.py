"""Benchmark harness entry point — one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run
"""
from __future__ import annotations

import json
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks import (ablation_modes, perf_compare, roofline, scaling,
                        spad_fit, sparse_decode, throughput, variants)


def main():
    os.makedirs("results/bench", exist_ok=True)
    out = {}
    print("\n" + "=" * 78)
    out["scaling_fig14"] = scaling.main()
    print("\n" + "=" * 78)
    out["variants_fig19_21"] = variants.main()
    print("\n" + "=" * 78)
    out["throughput_tableVI"] = throughput.main()
    print("\n" + "=" * 78)
    out["spad_fit_tableIII"] = spad_fit.main()
    print("\n" + "=" * 78)
    out["ablation_modes"] = ablation_modes.main()
    print("\n" + "=" * 78)
    out["roofline"] = roofline.main()
    print("\n" + "=" * 78)
    out["perf_compare"] = perf_compare.main()
    print("\n" + "=" * 78)
    out["sparse_decode"] = sparse_decode.main(smoke=True)
    with open("results/bench/summary.json", "w") as f:
        json.dump(out, f, indent=1, default=str)
    print("\nwrote results/bench/summary.json")
    return out


if __name__ == "__main__":
    main()
