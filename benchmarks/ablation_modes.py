"""Planner ablation (paper Fig. 9 argument): per-layer HM-NoC mode selection
vs forcing a single fixed mode for all weights — the quantitative case for
per-layer flexibility, evaluated with the planner's own roofline estimator
(no compilation; analytic, like the paper's Fig. 14 model).

A fixed-broadcast NoC is Eyeriss v1; the planner is Eyeriss v2.
"""
from __future__ import annotations

from typing import Dict

from repro.configs import SHAPES, get_config
from repro.core import planner
from repro.core.hmmesh import Mode
from repro.core.reuse import model_gemms

MESH = planner.MeshDesc(pod=1, data=16, model=16)
ARCHS = ("gemma2-2b", "qwen2.5-3b", "mixtral-8x7b", "mamba2-130m",
         "llama4-maverick-400b-a17b")
FORCED = (Mode.BROADCAST, Mode.GROUPED_MC, Mode.UNICAST)


def _model_time(cfg, shape, wm=None) -> float:
    training = shape.kind == "train"
    decode = shape.kind == "decode"
    tokens = shape.global_batch * (1 if decode else shape.seq_len)
    total = 0.0
    for g in model_gemms(cfg, max(tokens, 1), decode=decode):
        if wm is None:
            total += planner.plan_layer(g, MESH, training).est_time
        else:
            best = None
            for im in (Mode.BROADCAST, Mode.INTERLEAVED_MC):
                res = planner._candidate_time(g, wm, im, MESH, training)
                if res is not None and (best is None or res[0] < best):
                    best = res[0]
            # infeasible forced mode -> fall back to broadcast/broadcast
            if best is None:
                best = planner._candidate_time(
                    g, Mode.BROADCAST, Mode.BROADCAST, MESH, training)[0]
            total += best
    return total


def run() -> Dict:
    out: Dict = {}
    for arch in ARCHS:
        cfg = get_config(arch)
        for shape_name in ("train_4k", "decode_32k"):
            shape = SHAPES[shape_name]
            planned = _model_time(cfg, shape)
            rows = {"planner": 1.0}
            for wm in FORCED:
                rows[wm.value] = _model_time(cfg, shape, wm) / planned
            out[f"{arch}:{shape_name}"] = rows
    return out


def main() -> Dict:
    res = run()
    print("=== Planner ablation: est. step time, normalized to the planner "
          "(>1 = slower) ===")
    print(f"{'cell':40s} {'planner':>8s} {'bcast':>8s} {'grouped':>8s} "
          f"{'unicast':>8s}")
    for cell, rows in res.items():
        print(f"{cell:40s} {rows['planner']:8.2f} "
              f"{rows['broadcast']:8.2f} {rows['grouped_multicast']:8.2f} "
              f"{rows['unicast']:8.2f}")
    return res


if __name__ == "__main__":
    main()
