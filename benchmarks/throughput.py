"""Table VI reproduction: inference/sec at batch 1, 200 MHz, 192 PEs ×2 MACs.

Energy columns are out of scope (no power model on CPU — DESIGN.md §6);
throughput and the dense→sparse / AlexNet→MobileNet ratios are the
reproducible claims:
    paper: AlexNet 102.1 → sparse 278.7 inf/s; MobileNet 1282.1 → 1470.6;
           MobileNet/AlexNet dense ratio 12.6× ~ the 14.7× MAC reduction.
"""
from __future__ import annotations

from typing import Dict

from benchmarks.variants import N_PES, _acc, _cycles
from benchmarks.workloads import alexnet, mobilenet, total_macs

CLOCK_HZ = 200e6

PAPER = {
    "alexnet": 102.1, "sparse alexnet": 278.7,
    "mobilenet": 1282.1, "sparse mobilenet": 1470.6,
}


def run(batch: int = 1) -> Dict:
    acc = _acc("hmnoc", True)      # Eyeriss v2
    nets = {
        "alexnet": (alexnet(batch, False), False),
        "sparse alexnet": (alexnet(batch, True), True),
        "mobilenet": (mobilenet(batch, False), False),
        "sparse mobilenet": (mobilenet(batch, True), True),
    }
    out: Dict = {}
    for name, (layers, sparse) in nets.items():
        cycles = _cycles(layers, acc, sparse_skip=True)
        inf_s = CLOCK_HZ / max(cycles, 1.0) * batch
        out[name] = {
            "nominal_macs": total_macs(layers),
            "cycles": cycles,
            "inference_per_s": inf_s,
            "paper_inference_per_s": PAPER[name],
        }
    out["_ratios"] = {
        "mobilenet_over_alexnet":
            out["mobilenet"]["inference_per_s"] /
            out["alexnet"]["inference_per_s"],
        "paper_mobilenet_over_alexnet": PAPER["mobilenet"] / PAPER["alexnet"],
        "sparse_gain_alexnet":
            out["sparse alexnet"]["inference_per_s"] /
            out["alexnet"]["inference_per_s"],
        "sparse_gain_mobilenet":
            out["sparse mobilenet"]["inference_per_s"] /
            out["mobilenet"]["inference_per_s"],
    }
    return out


def decode_tokens_per_s(batches=(1, 4, 8), smoke: bool = False) -> Dict:
    """Dense-vs-sparse DecodeEngine tokens/sec (ISSUE 1) — the serving-side
    counterpart of Table VI's batch-1 rows. Delegates to
    benchmarks.sparse_decode so both reports share one harness."""
    from benchmarks.sparse_decode import decode_benchmark
    return decode_benchmark(batches=(1,) if smoke else batches,
                            max_new=4 if smoke else 8)


def main(decode: bool = False, smoke: bool = False) -> Dict:
    res = run()
    print("=== Table VI: Eyeriss v2 throughput (batch 1, 200 MHz) ===")
    print(f"{'DNN':18s} {'MACs':>10s} {'inf/s (model)':>14s} "
          f"{'inf/s (paper)':>14s}")
    for name, r in res.items():
        if name.startswith("_"):
            continue
        print(f"{name:18s} {r['nominal_macs'] / 1e6:9.1f}M "
              f"{r['inference_per_s']:14.1f} "
              f"{r['paper_inference_per_s']:14.1f}")
    r = res["_ratios"]
    print(f"MobileNet/AlexNet: model {r['mobilenet_over_alexnet']:.1f}x, "
          f"paper {r['paper_mobilenet_over_alexnet']:.1f}x")
    if decode:
        d = decode_tokens_per_s(smoke=smoke)
        res["_decode_tokens_per_s"] = d
        print("--- decode tokens/sec (dense vs BCSC-sparse serve path) ---")
        for b, row in d["batches"].items():
            print(f"  batch {b}: dense {row['dense']['tokens_per_s']:8.2f}"
                  f"  sparse {row['sparse']['tokens_per_s']:8.2f}")
    return res


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--decode", action="store_true",
                    help="also time the dense-vs-sparse decode serve path")
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    main(decode=args.decode, smoke=args.smoke)
