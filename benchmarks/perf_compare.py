"""§Perf before/after: paper-faithful planner baseline (results/dryrun) vs
the beyond-paper optimized build (results/dryrun_opt)."""
from __future__ import annotations

import glob
import json
import os
from typing import Dict

BASE = os.environ.get("DRYRUN_BASE", "results/dryrun")
OPT = os.environ.get("DRYRUN_OPT", "results/dryrun_opt")


def _load(d):
    out = {}
    for f in glob.glob(os.path.join(d, "*.json")):
        r = json.load(open(f))
        out[(r["arch"], r["shape"], r["mesh"])] = r
    return out


def main() -> Dict:
    base, opt = _load(BASE), _load(OPT)
    if not opt:
        print(f"no optimized records in {OPT} — run "
              "scripts/run_dryrun_all.sh results/dryrun_opt")
        return {}
    rows = []
    print("=== §Perf: baseline -> optimized (16x16; roofline fraction & "
          "dominant term) ===")
    print(f"{'cell':42s} {'roofl% b->a':>16s} {'t_dom b->a (s)':>20s} "
          f"{'HBM GB b->a':>14s}")
    for key in sorted(base):
        if key[2] != "16x16" or key not in opt:
            continue
        b, a = base[key], opt[key]
        if b["status"] != "ok" or a["status"] != "ok":
            continue
        tb = max(b["t_compute_s"], b["t_memory_s"], b["t_collective_s"])
        ta = max(a["t_compute_s"], a["t_memory_s"], a["t_collective_s"])
        rows.append({"cell": f"{key[0]}:{key[1]}",
                     "roofline_before": b["roofline_fraction"],
                     "roofline_after": a["roofline_fraction"],
                     "t_before": tb, "t_after": ta,
                     "speedup": tb / max(ta, 1e-12)})
        print(f"{key[0] + ':' + key[1]:42s} "
              f"{b['roofline_fraction'] * 100:6.2f}->"
              f"{a['roofline_fraction'] * 100:5.2f} "
              f"{tb:9.2e}->{ta:9.2e} "
              f"{b['hbm_per_chip_gb']:6.1f}->{a['hbm_per_chip_gb']:5.1f}")
    if rows:
        import statistics
        sp = [r["speedup"] for r in rows]
        print(f"\nmedian bound-term speedup {statistics.median(sp):.2f}x, "
              f"max {max(sp):.2f}x over {len(rows)} cells")
    return {"cells": rows}


if __name__ == "__main__":
    main()
