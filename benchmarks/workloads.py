"""DNN workloads the paper benchmarks with (layer shapes as Table-I dims).

AlexNet and MobileNet (width 0.5, input 128) follow the paper's benchmarking
setup (§V); GoogLeNet appears in the scalability study (Fig. 14). Sparsity
levels for the "sparse" variants follow the energy-aware-pruning results the
paper cites ([14]): CONV 40–75%, FC ~90% weight sparsity; ReLU-induced iact
sparsity grows with depth (Fig. 2 discussion).
"""
from __future__ import annotations

import dataclasses
from typing import List

from repro.core.reuse import LayerShape, conv, gemm


def _sp(layer: LayerShape, w: float, a: float) -> LayerShape:
    return dataclasses.replace(layer, sparsity_w=w, sparsity_a=a)


def alexnet(batch: int = 1, sparse: bool = False) -> List[LayerShape]:
    ls = [
        conv("CONV1", batch, 3, 96, 227, 227, 11, 11, u=4),
        conv("CONV2", batch, 48, 256, 31, 31, 5, 5, groups=2),
        conv("CONV3", batch, 256, 384, 15, 15, 3, 3),
        conv("CONV4", batch, 192, 384, 15, 15, 3, 3, groups=2),
        conv("CONV5", batch, 192, 256, 15, 15, 3, 3, groups=2),
        gemm("FC6", batch, 9216, 4096),
        gemm("FC7", batch, 4096, 4096),
        gemm("FC8", batch, 4096, 1000),
    ]
    if sparse:
        w = [0.16, 0.62, 0.65, 0.63, 0.63, 0.91, 0.91, 0.75]
        a = [0.0, 0.45, 0.60, 0.65, 0.65, 0.70, 0.75, 0.75]
        ls = [_sp(l, wi, ai) for l, wi, ai in zip(ls, w, a)]
    return ls


def mobilenet(batch: int = 1, sparse: bool = False,
              width: float = 0.5, res: int = 128) -> List[LayerShape]:
    """MobileNet v1 (paper benchmarks width 0.5 @ 128)."""
    def ch(c):
        return max(int(c * width), 8)

    ls = [conv("CONV1", batch, 3, ch(32), res, res, 3, 3, u=2)]
    spatial = res // 2
    cfgs = [  # (in, out, stride) for the 13 dw/pw pairs of v1
        (32, 64, 1), (64, 128, 2), (128, 128, 1), (128, 256, 2),
        (256, 256, 1), (256, 512, 2), (512, 512, 1), (512, 512, 1),
        (512, 512, 1), (512, 512, 1), (512, 512, 1), (512, 1024, 2),
        (1024, 1024, 1),
    ]
    for i, (cin, cout, s) in enumerate(cfgs, start=2):
        ls.append(conv(f"CONV{i}_DW", batch, 1, 1, spatial + 2, spatial + 2,
                       3, 3, u=s, groups=ch(cin)))
        spatial //= s
        ls.append(conv(f"CONV{i}_PW", batch, ch(cin), ch(cout),
                       spatial, spatial, 1, 1))
    ls.append(gemm("FC", batch, ch(1024), 1000))
    if sparse:
        out = []
        for l in ls:
            if "DW" in l.name:                 # depth-wise barely prunable
                out.append(_sp(l, 0.10, 0.40))
            elif l.name.startswith("FC"):
                out.append(_sp(l, 0.75, 0.60))
            elif l.name == "CONV1":
                out.append(_sp(l, 0.0, 0.0))
            else:
                out.append(_sp(l, 0.35, 0.50))
        ls = out
    return ls


def googlenet(batch: int = 1) -> List[LayerShape]:
    """Representative GoogLeNet layers (incl. the incp3a-red5x5 from Fig. 2)."""
    return [
        conv("CONV1", batch, 3, 64, 227, 227, 7, 7, u=2),
        conv("CONV2-red", batch, 64, 64, 56, 56, 1, 1),
        conv("CONV2", batch, 64, 192, 56, 56, 3, 3),
        conv("incp3a-red5x5", batch, 192, 16, 28, 28, 1, 1),
        conv("incp3a-5x5", batch, 16, 32, 28, 28, 5, 5),
        conv("incp3a-1x1", batch, 192, 64, 28, 28, 1, 1),
        conv("incp3a-3x3", batch, 96, 128, 28, 28, 3, 3),
        conv("incp4a-3x3", batch, 96, 208, 14, 14, 3, 3),
        conv("incp5b-1x1", batch, 832, 384, 7, 7, 1, 1),
        gemm("FC", batch, 1024, 1000),
    ]


NETWORKS = {
    "alexnet": alexnet,
    "mobilenet": mobilenet,
    "googlenet": googlenet,
}


def total_macs(layers) -> int:
    return sum(l.macs for l in layers)
