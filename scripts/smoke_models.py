"""Dev harness: run reduced-config loss/prefill/decode for every arch on CPU."""
import sys

import jax
import jax.numpy as jnp

from repro.configs import ARCH_NAMES, get_config
from repro.models import decoding, transformer as tfm


def make_batch(rng, cfg, B, S):
    ks = jax.random.split(rng, 4)
    S_text = S - cfg.num_patches if cfg.frontend == "vision" else S
    if cfg.num_codebooks > 1:
        toks = jax.random.randint(ks[0], (B, cfg.num_codebooks, S_text), 0,
                                  cfg.vocab_size)
    else:
        toks = jax.random.randint(ks[0], (B, S_text), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    if cfg.frontend == "vision":
        batch["patch_embeds"] = jax.random.normal(
            ks[1], (B, cfg.num_patches, cfg.d_model), jnp.bfloat16) * 0.02
    if cfg.cross_attn_cond:
        batch["cond"] = jax.random.normal(
            ks[2], (B, cfg.cross_attn_cond, cfg.d_model), jnp.bfloat16) * 0.02
    return batch


def main():
    names = sys.argv[1:] or ARCH_NAMES
    B, S = 2, 64
    for name in names:
        cfg = get_config(name).reduced()
        rng = jax.random.PRNGKey(0)
        params = tfm.init_params(rng, cfg)
        n_params = sum(x.size for x in jax.tree.leaves(params))
        batch = make_batch(rng, cfg, B, S)
        total, metrics = jax.jit(
            lambda p, b: tfm.loss_fn(p, b, cfg))(params, batch)
        assert jnp.isfinite(total), (name, total)
        # prefill + one decode step
        cache_len = S + 8
        logits, cache = jax.jit(
            lambda p, t, pe=None, cd=None: decoding.prefill(
                p, t, cfg, cache_len, patch_embeds=pe, cond=cd))(
            params, batch["tokens"], batch.get("patch_embeds"),
            batch.get("cond"))
        assert jnp.all(jnp.isfinite(logits.astype(jnp.float32)))
        if cfg.num_codebooks > 1:
            tok = batch["tokens"][:, :, -1:]
        else:
            tok = batch["tokens"][:, -1:]
        pos = jnp.int32(S if cfg.frontend != "vision" else S)
        logits2, cache2 = jax.jit(
            lambda p, c, t, q, cd=None: decoding.serve_step(
                p, c, t, q, cfg, cond=cd))(
            params, cache, tok, pos, batch.get("cond"))
        assert jnp.all(jnp.isfinite(logits2.astype(jnp.float32)))
        print(f"OK {name:28s} params={n_params:>10,} loss={float(total):.3f}")


if __name__ == "__main__":
    main()
