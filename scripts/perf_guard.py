"""Wall-clock-free perf regression guard (ISSUE 2 + ISSUE 3 CI tooling).

Runs after the sparse-decode benchmark in CI and fails the build when the
fused bcsc_mlp megakernel stops beating the two-call path on the
deterministic cost proxies — grid steps and HBM-bytes-moved — which hold in
interpret mode on CPU exactly as they do compiled on TPU (they count work,
not time). Wall-clock tokens/sec is *reported* by the benchmark but never
gated here: CI runners are too noisy for a timing gate.

Checks:
  1. fused grid steps  <= two-call grid steps        (within this run)
  2. fused HBM bytes   <  two-call HBM bytes         (strict, within run)
  3. fused HBM bytes   <  PR 1 recorded baseline     (strict, cross-PR)
  4. fused launches    <  two-call launches
  5. the batch-1 e2e ratio and per-phase breakdown are present (the
     benchmark actually measured what the JSON claims)
  6. paged KV (ISSUE 3): paged cache bytes strictly below the dense slot
     cache at 50% mean occupancy, and the paged decode kernel's work steps
     within ceil(len/page_size) per row (the pl.when skip bound)
  7. arrivals (ISSUE 3): continuous batching beats the drain-the-chunk
     baseline on goodput at high length variance — gated because both sides
     run on the deterministic virtual step clock, not wall time
  8. page-native KV (ISSUE 4): the paged prefill path's per-layer KV buffer
     is strictly smaller than the scatter path's dense (B, cache_len)
     transient (byte accounting — the allocation the refactor deleted);
     shared-prefix workloads admit strictly MORE concurrent requests than
     unshared admission at the same pool size, peak at fewer pages, and
     produce identical outputs; int8 KV pages record a quantized-vs-fp
     byte ratio strictly below 1
  9. chaos/overload (ISSUE 6): the degradation ladder sheds no more than
     admission-control-only shedding (and shed rate stays <= 0.5), degraded
     goodput stays within 5% of (in practice above) the shed-only floor,
     and the injected-fault run keeps every request terminal with a clean
     pool audit and bit-identical surviving tokens — all on the virtual
     step clock
 10. replica failover (ISSUE 7): killing 1 of N replicas mid-sweep leaves
     non-migrated survivors bit-identical and every rid terminal, fleet
     goodput holds >= 0.9x the fault-free run (the recompute tax bound),
     and prefix-affinity placement achieves strictly more CoW page sharing
     on shared-prompt traffic than affinity-free placement
 11. plan snapshot (ISSUE 5): the resolved ServePlans for the seed configs
     (core.plan.snapshot_plan — fixed budget/shape inputs) match
     scripts/golden_plans.json exactly. Any drift in a dispatch decision,
     threshold, pool size, or bound rationale fails CI until the golden
     file is regenerated deliberately:
        PYTHONPATH=src python -c "import json; from repro.core import plan;
        json.dump({a: plan.snapshot_plan(a).as_dict() for a in
        plan.SNAPSHOT_CONFIGS}, open('scripts/golden_plans.json','w'),
        indent=2, sort_keys=True)"
 12. speculative decode (ISSUE 9): batch-1 draft/verify speculation retires
     >= 1.5x tokens per decode dispatch (one flattened k-position verify
     per round vs one step per baseline token — the deterministic-clock
     speedup; wall seconds are reported but never gated), and the greedy
     token streams are bit-identical to the sequential baseline at every
     benchmarked batch size
 13. mesh-sharded serving (ISSUE 10): sharded stream() (tp attention
     shards, ep expert shards) emits token streams bit-identical to
     single-device, the per-device KV pool holds <= 1/tp of the
     single-device pool plus one page frame of rounding slack, and the
     sharded ServePlans for mixtral-8x7b / llama4-maverick-400b-a17b at
     both canonical mesh shapes match golden_plans.json["__sharded__"]
     exactly. Regenerate the golden (deliberately) with:
        PYTHONPATH=src python -c "import json; from repro.core import plan;
        g = {a: plan.snapshot_plan(a).as_dict() for a in
             plan.SNAPSHOT_CONFIGS};
        g['__sharded__'] = {a: {m: plan.snapshot_sharded_plan(a, m)
            .as_dict() for m in plan.SHARDED_SNAPSHOT_MESHES}
            for a in plan.SHARDED_SNAPSHOT_CONFIGS};
        json.dump(g, open('scripts/golden_plans.json','w'), indent=2,
        sort_keys=True)"

    PYTHONPATH=src python scripts/perf_guard.py [BENCH_sparse_decode.json]
"""
from __future__ import annotations

import json
import os
import sys

# PR 1's two-call path at the benchmark config (qwen2.5-3b-reduced, 0.75
# block sparsity, bm=8, 16x16 blocks): every projection kernel walked the
# padded stack capacity and round-tripped the hidden activation through HBM.
# These are the mlp_proxy "two_call" numbers for that packing — the recorded
# baseline the fused path must strictly beat.
PR1_TWO_CALL_HBM_BYTES = 99_072
PR1_TWO_CALL_GRID_STEPS = 96


def main(path: str = "BENCH_sparse_decode.json") -> int:
    data = json.load(open(path))
    mp = data["mlp_proxy"]
    fused, two = mp["fused"], mp["two_call"]
    failures = []

    def check(name, ok, detail):
        print(f"  [{'ok' if ok else 'FAIL'}] {name}: {detail}")
        if not ok:
            failures.append(name)

    print(f"perf guard on {path} "
          f"(arch {mp['arch']}, sparsity {mp['sparsity']})")
    check("grid-steps", fused["grid_steps"] <= two["grid_steps"],
          f"fused {fused['grid_steps']} <= two-call {two['grid_steps']}")
    check("hbm-bytes", fused["hbm_bytes"] < two["hbm_bytes"],
          f"fused {fused['hbm_bytes']} < two-call {two['hbm_bytes']}")
    check("hbm-bytes-vs-pr1", fused["hbm_bytes"] < PR1_TWO_CALL_HBM_BYTES,
          f"fused {fused['hbm_bytes']} < PR1 baseline "
          f"{PR1_TWO_CALL_HBM_BYTES}")
    check("grid-steps-vs-pr1",
          fused["grid_steps"] <= PR1_TWO_CALL_GRID_STEPS,
          f"fused {fused['grid_steps']} <= PR1 baseline "
          f"{PR1_TWO_CALL_GRID_STEPS}")
    check("kernel-launches",
          fused["kernel_launches"] < two["kernel_launches"],
          f"fused {fused['kernel_launches']} < two-call "
          f"{two['kernel_launches']}")

    pg = data.get("paged", {})
    if pg:
        check("paged-hbm-bytes", pg["paged_bytes"] < pg["dense_slot_bytes"],
              f"paged {pg['paged_bytes']} < dense slot "
              f"{pg['dense_slot_bytes']} at {pg['mean_occupancy']:.0%} "
              f"occupancy ({pg['bytes_ratio']:.2f}x)")
        # work_steps comes from the kernel's own skip expression
        # (kernels.paged_attention.row_work_steps — shared with its pl.when
        # guard), ceil_pages from core.dataflow: a kernel-side skip
        # regression moves the left side and trips one of these
        check("paged-grid-steps", pg["work_steps"] <= pg["ceil_pages"],
              f"kernel work steps {pg['work_steps']} <= spec ceil(len/ps) "
              f"sum {pg['ceil_pages']}")
        check("paged-skip-saves-steps",
              pg["work_steps"] < pg["padded_grid_steps"],
              f"kernel work steps {pg['work_steps']} < padded grid "
              f"{pg['padded_grid_steps']} (ragged rows must skip)")
    else:
        print("  [--] paged section absent; paged gates skipped")

    ar = data.get("arrivals", {})
    if ar:
        hv = ar["cases"]["high_variance"]
        check("continuous-beats-drain",
              hv["goodput_ratio"] > 1.0,
              f"scheduler/drain goodput x{hv['goodput_ratio']:.2f} at "
              f"variance x{ar['variance_ratio']:.0f} (virtual-step clock)")
        check("arrival-latency-reported",
              all(k in hv["scheduler"] for k in
                  ("latency_p50_steps", "latency_p99_steps")),
              f"p50 {hv['scheduler'].get('latency_p50_steps')} "
              f"p99 {hv['scheduler'].get('latency_p99_steps')}")
    else:
        print("  [--] arrivals section absent (--no-arrivals run); "
              "goodput gate skipped")

    sp = data.get("shared_prefix", {})
    if sp:
        pt = sp["prefill_transient"]
        check("paged-prefill-transient",
              pt["paged_path_bytes"] < pt["scatter_path_bytes"],
              f"page-native {pt['paged_path_bytes']} B (tier {pt['tier']}) "
              f"< scatter-path dense transient {pt['scatter_path_bytes']} B")
        sh, un = sp["shared"], sp["unshared"]
        check("shared-prefix-concurrency",
              sh["peak_live_rows"] > un["peak_live_rows"],
              f"shared admits {sh['peak_live_rows']} concurrent > unshared "
              f"{un['peak_live_rows']} at {sp['num_pages']} pages")
        check("shared-prefix-pages",
              sh["pages_peak"]["pages_used"] < un["pages_peak"]["pages_used"],
              f"shared peaks at {sh['pages_peak']['pages_used']} pages < "
              f"unshared {un['pages_peak']['pages_used']}")
        check("shared-prefix-outputs-identical",
              sp["outputs_identical"],
              "CoW sharing is output-transparent")
        kq = sp["kv_quant"]
        check("kv-quant-bytes-ratio",
              0.0 < kq["int8_vs_fp_ratio"] < 1.0,
              f"int8 pages {kq['int8_cache_bytes']} B / fp "
              f"{kq['fp_cache_bytes']} B = {kq['int8_vs_fp_ratio']:.2f}")
    else:
        print("  [--] shared_prefix section absent; page-native gates "
              "skipped")

    ch = data.get("chaos", {})
    if ch:
        so, la, fa = ch["shed_only"], ch["ladder"], ch["faulted"]
        check("shed-rate-bounded",
              la["shed_rate"] <= so["shed_rate"] and so["shed_rate"] <= 0.5,
              f"ladder {la['shed_rate']:.2f} <= shed-only "
              f"{so['shed_rate']:.2f} <= 0.5 of {ch['n_requests']} requests")
        check("degraded-goodput-floor",
              la["goodput_tokens_per_step"] >=
              0.95 * so["goodput_tokens_per_step"],
              f"ladder {la['goodput_tokens_per_step']:.3f} tok/step >= 0.95"
              f" x shed-only {so['goodput_tokens_per_step']:.3f} "
              f"(x{ch['goodput_vs_shed_only']:.2f}, final kv "
              f"{la['kv_quant_final']})")
        check("chaos-terminal-outcomes",
              all(r["all_terminal"] and r["pool_audit_clean"]
                  for r in (so, la, fa)),
              "every request terminal + per-sync pool audits clean in all "
              "three runs")
        check("chaos-survivors-bit-identical",
              ch["survivors_bit_identical"] and ch["survivors_compared"] > 0,
              f"{ch['survivors_compared']} requests ok in both faulted and "
              f"fault-free runs, tokens identical "
              f"(injected: {fa['chaos_injected']})")
    else:
        print("  [--] chaos section absent; overload/degradation gates "
              "skipped")

    rf = data.get("replica_failover", {})
    if rf:
        ff, ki = rf["fault_free"], rf["killed"]
        check("failover-survivors-bit-identical",
              rf["survivors_bit_identical"] and rf["survivors_compared"] > 0
              and all(r["all_terminal"] for r in (ff, ki)),
              f"{rf['survivors_compared']} non-migrated survivors "
              f"bit-identical after killing 1 of {rf['replicas']} replicas "
              f"at step {rf['kill_step']:g}; every rid terminal in both "
              f"runs (migrated identical: {rf['migrated_bit_identical']})")
        check("failover-goodput-floor",
              rf["failover_goodput_ratio"] >= 0.9,
              f"killed {ki['goodput_tokens_per_step']:.3f} tok/step >= 0.9 "
              f"x fault-free {ff['goodput_tokens_per_step']:.3f} "
              f"(x{rf['failover_goodput_ratio']:.2f} with "
              f"{ki['migrated_requests']} migrations)")
        check("router-prefix-affinity",
              rf["fault_free"]["shared_tokens_admitted"] >
              rf["no_affinity"]["shared_tokens_admitted"],
              f"affinity placement shares "
              f"{rf['fault_free']['shared_tokens_admitted']} prompt tokens "
              f"from adopted pages vs "
              f"{rf['no_affinity']['shared_tokens_admitted']} without "
              f"(x{rf['affinity_sharing_ratio']:.1f})")
    else:
        print("  [--] replica_failover section absent; failover gates "
              "skipped")

    tl = data.get("telemetry", {})
    if tl:
        check("trace-deterministic",
              tl["trace_deterministic"] and tl["span_count"] > 0,
              f"two same-seed chaos runs, identical trace signatures with "
              f"wall-clock stripped ({tl['span_count']} spans, injected "
              f"{tl['chaos_injected_kinds']})")
        # both directions: the accurate plan stays clean AND the
        # mispredicted plan fires naming the paging decision — a drift
        # detector that never fires is as dead as one that always fires
        check("plan-drift-clean",
              not tl["clean_drift"]["confirmed"]
              and tl["clean_drift"]["compared"] > 0
              and tl["forced_names_attention"],
              f"accurate plan: {tl['clean_drift']['compared']} comparisons "
              f"clean over {tl['clean_drift']['windows']} windows; "
              f"mispredicted plan confirms "
              f"{tl['forced_drift']['confirmed']}")
    else:
        print("  [--] telemetry section absent; trace/drift gates skipped")

    plans = data.get("plans", {})
    if plans:
        golden_path = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                   "golden_plans.json")
        golden = json.load(open(golden_path))
        # round-trip the bench side through JSON text so tuple-vs-list and
        # int-vs-float representation can never cause a spurious diff
        plans = json.loads(json.dumps(plans))
        drifted = []
        # both directions: a bench plan without a golden counterpart (new
        # snapshot config, golden not regenerated) is drift too. "__"-keys
        # hold auxiliary snapshot families (e.g. __sharded__) gated below.
        for arch in sorted(k for k in set(golden) | set(plans)
                           if not k.startswith("__")):
            want, got = golden.get(arch), plans.get(arch)
            if got != want:
                if want is None or got is None:
                    keys = "missing from golden" if want is None \
                        else "missing from bench"
                else:
                    keys = ", ".join(sorted(
                        k for k in set(want) | set(got)
                        if got.get(k) != want.get(k)))
                drifted.append(f"{arch}({keys})")
        check("plan-snapshot-stable", not drifted,
              f"{len(golden)} seed plans match scripts/golden_plans.json"
              if not drifted else f"drifted: {'; '.join(drifted)}")
    else:
        print("  [--] plans section absent; plan-snapshot gate skipped")

    splans = data.get("sharded_plans", {})
    if splans:
        golden_path = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                   "golden_plans.json")
        golden_sharded = json.load(open(golden_path)).get("__sharded__", {})
        splans = json.loads(json.dumps(splans))
        drifted = []
        for arch in sorted(set(golden_sharded) | set(splans)):
            want = golden_sharded.get(arch, {})
            got = splans.get(arch, {})
            for mesh in sorted(set(want) | set(got)):
                if got.get(mesh) != want.get(mesh):
                    drifted.append(f"{arch}@{mesh}")
        check("sharded-plan-snapshot-stable", not drifted,
              f"{sum(len(v) for v in golden_sharded.values())} sharded "
              "plans match golden __sharded__"
              if not drifted else f"drifted: {'; '.join(drifted)}")
    else:
        print("  [--] sharded_plans section absent; sharded-snapshot "
              "gate skipped")

    shp = data.get("shard_proxy", {})
    if shp:
        cases = shp.get("cases", {})
        check("sharded-outputs-identical",
              bool(cases) and all(c.get("outputs_identical") is True
                                  for c in cases.values()),
              "sharded stream() vs single-device: " + ", ".join(
                  f"{name}: {c.get('outputs_identical')}"
                  for name, c in sorted(cases.items())))
        # per-device KV pool holds <= 1/tp of the single-device pool plus
        # one page frame of rounding slack (whole local frames only)
        pool_ok, detail = True, []
        for name, c in sorted(cases.items()):
            if not c.get("paged") or c.get("tp", 1) <= 1:
                continue
            bound = (c["kv_bytes_single_device"] / c["tp"]
                     + c["page_frame_bytes_per_device"])
            ok = c["kv_bytes_per_device"] <= bound
            pool_ok &= ok
            detail.append(f"{name}: {c['kv_bytes_per_device']:,} B <= "
                          f"{bound:,.0f} B ({'ok' if ok else 'OVER'})")
        check("sharded-pool-bytes-per-device", pool_ok,
              "; ".join(detail) if detail
              else "no paged tp case benchmarked")
    else:
        print("  [--] shard_proxy section absent; sharded gates skipped")

    spd = data.get("spec_proxy", {})
    if spd:
        s1 = spd.get("batches", {}).get("1", {})
        # speedup is tokens-per-dispatch on the deterministic clock (one
        # flattened verify per speculative round vs one step per baseline
        # token) — wall seconds are reported alongside but never gated
        check("spec-decode-speedup",
              s1.get("speedup_tokens_per_dispatch", 0) >= 1.5,
              f"batch-1 spec x{s1.get('speedup_tokens_per_dispatch', 0):.2f}"
              f" tokens/dispatch (wall x{s1.get('speedup_wall', 0):.2f}, "
              f"acceptance "
              f"{s1.get('spec', {}).get('acceptance_rate', 0):.0%})")
        check("spec-greedy-bit-exact",
              all(row.get("greedy_bit_exact") is True
                  for row in spd["batches"].values()),
              "greedy outputs vs sequential baseline: " + ", ".join(
                  f"batch {b}: {row.get('greedy_bit_exact')}"
                  for b, row in sorted(spd["batches"].items())))
    else:
        print("  [--] spec_proxy section absent; spec-decode gates skipped")

    dec = data.get("decode", {})
    if dec:
        b1 = dec.get("batches", {}).get("1", {})
        check("e2e-ratio-reported", "e2e_ratio" in b1,
              f"batch-1 e2e ratio = {b1.get('e2e_ratio')}")
        ph = b1.get("sparse", {}).get("phases", {})
        check("phase-breakdown-reported",
              ph.get("prefill_batches", 0) >= 1 and "decode_s" in ph,
              f"prefill_batches={ph.get('prefill_batches')} "
              f"prefill_s={ph.get('prefill_s')}")
    else:
        print("  [--] engine section absent (--no-engine run); "
              "proxy checks only")

    if failures:
        print(f"PERF GUARD FAILED: {', '.join(failures)}")
        return 1
    print("perf guard passed")
    return 0


if __name__ == "__main__":
    sys.exit(main(*sys.argv[1:]))
