"""Wall-clock-free perf regression guard (ISSUE 2, CI tooling satellite).

Runs after the sparse-decode benchmark in CI and fails the build when the
fused bcsc_mlp megakernel stops beating the two-call path on the
deterministic cost proxies — grid steps and HBM-bytes-moved — which hold in
interpret mode on CPU exactly as they do compiled on TPU (they count work,
not time). Wall-clock tokens/sec is *reported* by the benchmark but never
gated here: CI runners are too noisy for a timing gate.

Checks:
  1. fused grid steps  <= two-call grid steps        (within this run)
  2. fused HBM bytes   <  two-call HBM bytes         (strict, within run)
  3. fused HBM bytes   <  PR 1 recorded baseline     (strict, cross-PR)
  4. fused launches    <  two-call launches
  5. the batch-1 e2e ratio and per-phase breakdown are present (the
     benchmark actually measured what the JSON claims)

    PYTHONPATH=src python scripts/perf_guard.py [BENCH_sparse_decode.json]
"""
from __future__ import annotations

import json
import sys

# PR 1's two-call path at the benchmark config (qwen2.5-3b-reduced, 0.75
# block sparsity, bm=8, 16x16 blocks): every projection kernel walked the
# padded stack capacity and round-tripped the hidden activation through HBM.
# These are the mlp_proxy "two_call" numbers for that packing — the recorded
# baseline the fused path must strictly beat.
PR1_TWO_CALL_HBM_BYTES = 99_072
PR1_TWO_CALL_GRID_STEPS = 96


def main(path: str = "BENCH_sparse_decode.json") -> int:
    data = json.load(open(path))
    mp = data["mlp_proxy"]
    fused, two = mp["fused"], mp["two_call"]
    failures = []

    def check(name, ok, detail):
        print(f"  [{'ok' if ok else 'FAIL'}] {name}: {detail}")
        if not ok:
            failures.append(name)

    print(f"perf guard on {path} "
          f"(arch {mp['arch']}, sparsity {mp['sparsity']})")
    check("grid-steps", fused["grid_steps"] <= two["grid_steps"],
          f"fused {fused['grid_steps']} <= two-call {two['grid_steps']}")
    check("hbm-bytes", fused["hbm_bytes"] < two["hbm_bytes"],
          f"fused {fused['hbm_bytes']} < two-call {two['hbm_bytes']}")
    check("hbm-bytes-vs-pr1", fused["hbm_bytes"] < PR1_TWO_CALL_HBM_BYTES,
          f"fused {fused['hbm_bytes']} < PR1 baseline "
          f"{PR1_TWO_CALL_HBM_BYTES}")
    check("grid-steps-vs-pr1",
          fused["grid_steps"] <= PR1_TWO_CALL_GRID_STEPS,
          f"fused {fused['grid_steps']} <= PR1 baseline "
          f"{PR1_TWO_CALL_GRID_STEPS}")
    check("kernel-launches",
          fused["kernel_launches"] < two["kernel_launches"],
          f"fused {fused['kernel_launches']} < two-call "
          f"{two['kernel_launches']}")

    dec = data.get("decode", {})
    if dec:
        b1 = dec.get("batches", {}).get("1", {})
        check("e2e-ratio-reported", "e2e_ratio" in b1,
              f"batch-1 e2e ratio = {b1.get('e2e_ratio')}")
        ph = b1.get("sparse", {}).get("phases", {})
        check("phase-breakdown-reported",
              ph.get("prefill_batches", 0) >= 1 and "decode_s" in ph,
              f"prefill_batches={ph.get('prefill_batches')} "
              f"prefill_s={ph.get('prefill_s')}")
    else:
        print("  [--] engine section absent (--no-engine run); "
              "proxy checks only")

    if failures:
        print(f"PERF GUARD FAILED: {', '.join(failures)}")
        return 1
    print("perf guard passed")
    return 0


if __name__ == "__main__":
    sys.exit(main(*sys.argv[1:]))
